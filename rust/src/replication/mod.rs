//! Replication strategies (paper §5, Table 1), generalized to N-way
//! replica groups.
//!
//! A [`Strategy`] maps the primary's persistency-model events — `clwb`
//! (dirty line identified), `sfence` (ordering point / epoch boundary),
//! `dfence` (durability point / transaction end) — onto RDMA verbs
//! against the replica-group [`Fabric`]:
//!
//! | event   | NO-SM | SM-RC     | SM-OB        | SM-DD          |
//! |---------|-------|-----------|--------------|----------------|
//! | clwb    | —     | write()   | write_wt()   | write_nt() @QP0|
//! | sfence  | —     | rcommit() | rofence()    | — (implicit)   |
//! | dfence  | —     | rcommit() | rdfence()    | read(sentinel) |
//!
//! plus the model-driven adaptive strategy (ours) that picks SM-OB or
//! SM-DD per transaction using the AOT latency model. The fabric fans
//! every verb out to all backups; blocking fences complete per the
//! group's ack policy (all / quorum), so a strategy is written once and
//! works for any group size.

pub mod adaptive;
pub mod strategies;

pub use adaptive::{ControlPlane, KnobPredictor, Predictor, SmAd};
pub use strategies::{NoSm, SmDd, SmOb, SmRc};

use crate::config::StrategyKind;
use crate::net::{Fabric, WriteMeta};
use crate::sim::ThreadClock;
use crate::Ns;
use anyhow::{bail, Result};

/// Hint describing the shape of an upcoming transaction (adaptive use).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TxnShape {
    /// Expected epochs per transaction.
    pub epochs: f32,
    /// Expected writes per epoch.
    pub writes: f32,
}

/// Decision/feedback counters an adaptive strategy exposes; all zeros
/// for fixed strategies. Flows RunOutcome -> GroupReport so benches and
/// reports can assert on controller behaviour.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DecisionStats {
    /// Transactions routed to SM-OB / SM-DD behaviour.
    pub chose_ob: u64,
    pub chose_dd: u64,
    /// Times the applied knob vector (mode, quorum, cap) changed.
    pub adaptive_switches: u64,
    /// Decision histogram over the chosen ack quorum (index = k).
    pub quorum_hist: Vec<u64>,
    /// Decision histogram over the chosen batch cap, sorted by cap.
    pub cap_hist: Vec<(usize, u64)>,
    /// Measured-latency feedback samples absorbed.
    pub feedback_samples: u64,
    /// Sum of per-sample |measured - predicted| / predicted * 100.
    pub err_pct_sum: f64,
}

impl DecisionStats {
    /// Mean model-vs-measured relative error over the feedback samples.
    pub fn mean_err_pct(&self) -> f64 {
        if self.feedback_samples == 0 {
            0.0
        } else {
            self.err_pct_sum / self.feedback_samples as f64
        }
    }

    /// Merge another lane's counters into this one (sharded groups).
    pub fn add(&mut self, other: &DecisionStats) {
        self.chose_ob += other.chose_ob;
        self.chose_dd += other.chose_dd;
        self.adaptive_switches += other.adaptive_switches;
        if self.quorum_hist.len() < other.quorum_hist.len() {
            self.quorum_hist.resize(other.quorum_hist.len(), 0);
        }
        for (k, n) in other.quorum_hist.iter().enumerate() {
            self.quorum_hist[k] += n;
        }
        for &(cap, n) in &other.cap_hist {
            match self.cap_hist.iter_mut().find(|(c, _)| *c == cap) {
                Some((_, m)) => *m += n,
                None => self.cap_hist.push((cap, n)),
            }
        }
        self.cap_hist.sort_unstable_by_key(|(c, _)| *c);
        self.feedback_samples += other.feedback_samples;
        self.err_pct_sum += other.err_pct_sum;
    }

    /// Subtract a warmup watermark (steady-state accounting, mirroring
    /// the scalar counter `_zero` snapshots in the scheduler).
    pub fn minus(&self, zero: &DecisionStats) -> DecisionStats {
        let at = |v: &[u64], i: usize| v.get(i).copied().unwrap_or(0);
        DecisionStats {
            chose_ob: self.chose_ob - zero.chose_ob,
            chose_dd: self.chose_dd - zero.chose_dd,
            adaptive_switches: self.adaptive_switches - zero.adaptive_switches,
            quorum_hist: (0..self.quorum_hist.len())
                .map(|k| self.quorum_hist[k] - at(&zero.quorum_hist, k))
                .collect(),
            cap_hist: self
                .cap_hist
                .iter()
                .map(|&(cap, n)| {
                    let z = zero
                        .cap_hist
                        .iter()
                        .find(|(c, _)| *c == cap)
                        .map_or(0, |&(_, m)| m);
                    (cap, n - z)
                })
                .collect(),
            feedback_samples: self.feedback_samples - zero.feedback_samples,
            err_pct_sum: self.err_pct_sum - zero.err_pct_sum,
        }
    }
}

/// A replication strategy: reacts to the primary's persistency events.
pub trait Strategy {
    fn kind(&self) -> StrategyKind;

    /// A dirty persistent line was identified (`clwb`): replicate it.
    fn on_clwb(&mut self, fabric: &mut Fabric, t: &mut ThreadClock, meta: WriteMeta);

    /// Ordering point (`sfence` between epochs).
    fn on_ofence(&mut self, fabric: &mut Fabric, t: &mut ThreadClock);

    /// Durability point (transaction end).
    fn on_dfence(&mut self, fabric: &mut Fabric, t: &mut ThreadClock);

    /// Transaction start (shape hint for adaptive strategies).
    fn on_txn_begin(
        &mut self,
        _fabric: &mut Fabric,
        _t: &mut ThreadClock,
        _hint: Option<TxnShape>,
    ) {
    }

    /// Transaction committed: measured commit latency feedback for the
    /// adaptive control plane (`hint` is the shape passed at begin).
    fn on_txn_end(&mut self, _hint: Option<TxnShape>, _commit_ns: Ns) {}

    /// Controller decision counters (all-zero for fixed strategies).
    fn decision_stats(&self) -> DecisionStats {
        DecisionStats::default()
    }
}

/// Construct a strategy by kind. `SmAd` takes the prediction function
/// (wired to the PJRT runtime by the caller, or the closed-form
/// fallback); constructing `SmAd` without one is a configuration error.
pub fn make_strategy(
    kind: StrategyKind,
    predictor: Option<Predictor>,
) -> Result<Box<dyn Strategy>> {
    Ok(match kind {
        StrategyKind::NoSm => Box::new(NoSm),
        StrategyKind::SmRc => Box::new(SmRc),
        StrategyKind::SmOb => Box::new(SmOb),
        StrategyKind::SmDd => Box::new(SmDd),
        StrategyKind::SmAd => match predictor {
            Some(p) => Box::new(SmAd::new(p)),
            None => bail!("SmAd requires a predictor; see runtime::model"),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_all_fixed_strategies() {
        // TABLE = the predictor-free fixed strategies; the full ALL set
        // additionally carries SmAd, which the factory rejects without
        // a predictor (covered below).
        for kind in StrategyKind::TABLE {
            let s = make_strategy(kind, None).unwrap();
            assert_eq!(s.kind(), kind);
        }
        assert_eq!(StrategyKind::ALL.len(), StrategyKind::TABLE.len() + 1);
        for kind in StrategyKind::ALL {
            let s = make_strategy(kind, Some(Box::new(|_, _| (1.0, 2.0)))).unwrap();
            assert_eq!(s.kind(), kind, "ALL must build with a predictor supplied");
        }
    }

    #[test]
    fn adaptive_requires_predictor() {
        let err = make_strategy(StrategyKind::SmAd, None).unwrap_err();
        assert!(
            err.to_string().contains("SmAd requires a predictor"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn adaptive_builds_with_predictor() {
        let s = make_strategy(StrategyKind::SmAd, Some(Box::new(|_, _| (1.0, 2.0))))
            .unwrap();
        assert_eq!(s.kind(), StrategyKind::SmAd);
    }
}
