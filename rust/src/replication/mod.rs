//! Replication strategies (paper §5, Table 1).
//!
//! A [`Strategy`] maps the primary's persistency-model events — `clwb`
//! (dirty line identified), `sfence` (ordering point / epoch boundary),
//! `dfence` (durability point / transaction end) — onto RDMA verbs:
//!
//! | event   | NO-SM | SM-RC     | SM-OB        | SM-DD          |
//! |---------|-------|-----------|--------------|----------------|
//! | clwb    | —     | write()   | write_wt()   | write_nt() @QP0|
//! | sfence  | —     | rcommit() | rofence()    | — (implicit)   |
//! | dfence  | —     | rcommit() | rdfence()    | read(sentinel) |
//!
//! plus the model-driven adaptive strategy (ours) that picks SM-OB or
//! SM-DD per transaction using the AOT latency model.

pub mod adaptive;
pub mod strategies;

pub use adaptive::{Predictor, SmAd};
pub use strategies::{NoSm, SmDd, SmOb, SmRc};

use crate::config::StrategyKind;
use crate::net::{Rdma, WriteMeta};
use crate::sim::ThreadClock;

/// Hint describing the shape of an upcoming transaction (adaptive use).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TxnShape {
    /// Expected epochs per transaction.
    pub epochs: f32,
    /// Expected writes per epoch.
    pub writes: f32,
}

/// A replication strategy: reacts to the primary's persistency events.
pub trait Strategy {
    fn kind(&self) -> StrategyKind;

    /// A dirty persistent line was identified (`clwb`): replicate it.
    fn on_clwb(&mut self, rdma: &mut Rdma, t: &mut ThreadClock, meta: WriteMeta);

    /// Ordering point (`sfence` between epochs).
    fn on_ofence(&mut self, rdma: &mut Rdma, t: &mut ThreadClock);

    /// Durability point (transaction end).
    fn on_dfence(&mut self, rdma: &mut Rdma, t: &mut ThreadClock);

    /// Transaction start (shape hint for adaptive strategies).
    fn on_txn_begin(
        &mut self,
        _rdma: &mut Rdma,
        _t: &mut ThreadClock,
        _hint: Option<TxnShape>,
    ) {
    }
}

/// Construct a strategy by kind. `SmAd` takes the prediction function
/// (wired to the PJRT runtime by the caller, or the closed-form fallback).
pub fn make_strategy(
    kind: StrategyKind,
    predictor: Option<Predictor>,
) -> Box<dyn Strategy> {
    match kind {
        StrategyKind::NoSm => Box::new(NoSm),
        StrategyKind::SmRc => Box::new(SmRc),
        StrategyKind::SmOb => Box::new(SmOb),
        StrategyKind::SmDd => Box::new(SmDd),
        StrategyKind::SmAd => Box::new(SmAd::new(
            predictor.expect("SmAd requires a predictor; see runtime::model"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_all_fixed_strategies() {
        for kind in StrategyKind::ALL {
            let s = make_strategy(kind, None);
            assert_eq!(s.kind(), kind);
        }
    }

    #[test]
    #[should_panic(expected = "SmAd requires a predictor")]
    fn adaptive_requires_predictor() {
        let _ = make_strategy(StrategyKind::SmAd, None);
    }
}
