//! Replication strategies (paper §5, Table 1), generalized to N-way
//! replica groups.
//!
//! A [`Strategy`] maps the primary's persistency-model events — `clwb`
//! (dirty line identified), `sfence` (ordering point / epoch boundary),
//! `dfence` (durability point / transaction end) — onto RDMA verbs
//! against the replica-group [`Fabric`]:
//!
//! | event   | NO-SM | SM-RC     | SM-OB        | SM-DD          |
//! |---------|-------|-----------|--------------|----------------|
//! | clwb    | —     | write()   | write_wt()   | write_nt() @QP0|
//! | sfence  | —     | rcommit() | rofence()    | — (implicit)   |
//! | dfence  | —     | rcommit() | rdfence()    | read(sentinel) |
//!
//! plus the model-driven adaptive strategy (ours) that picks SM-OB or
//! SM-DD per transaction using the AOT latency model. The fabric fans
//! every verb out to all backups; blocking fences complete per the
//! group's ack policy (all / quorum), so a strategy is written once and
//! works for any group size.

pub mod adaptive;
pub mod strategies;

pub use adaptive::{Predictor, SmAd};
pub use strategies::{NoSm, SmDd, SmOb, SmRc};

use crate::config::StrategyKind;
use crate::net::{Fabric, WriteMeta};
use crate::sim::ThreadClock;
use anyhow::{bail, Result};

/// Hint describing the shape of an upcoming transaction (adaptive use).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TxnShape {
    /// Expected epochs per transaction.
    pub epochs: f32,
    /// Expected writes per epoch.
    pub writes: f32,
}

/// A replication strategy: reacts to the primary's persistency events.
pub trait Strategy {
    fn kind(&self) -> StrategyKind;

    /// A dirty persistent line was identified (`clwb`): replicate it.
    fn on_clwb(&mut self, fabric: &mut Fabric, t: &mut ThreadClock, meta: WriteMeta);

    /// Ordering point (`sfence` between epochs).
    fn on_ofence(&mut self, fabric: &mut Fabric, t: &mut ThreadClock);

    /// Durability point (transaction end).
    fn on_dfence(&mut self, fabric: &mut Fabric, t: &mut ThreadClock);

    /// Transaction start (shape hint for adaptive strategies).
    fn on_txn_begin(
        &mut self,
        _fabric: &mut Fabric,
        _t: &mut ThreadClock,
        _hint: Option<TxnShape>,
    ) {
    }
}

/// Construct a strategy by kind. `SmAd` takes the prediction function
/// (wired to the PJRT runtime by the caller, or the closed-form
/// fallback); constructing `SmAd` without one is a configuration error.
pub fn make_strategy(
    kind: StrategyKind,
    predictor: Option<Predictor>,
) -> Result<Box<dyn Strategy>> {
    Ok(match kind {
        StrategyKind::NoSm => Box::new(NoSm),
        StrategyKind::SmRc => Box::new(SmRc),
        StrategyKind::SmOb => Box::new(SmOb),
        StrategyKind::SmDd => Box::new(SmDd),
        StrategyKind::SmAd => match predictor {
            Some(p) => Box::new(SmAd::new(p)),
            None => bail!("SmAd requires a predictor; see runtime::model"),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_all_fixed_strategies() {
        // TABLE = the predictor-free fixed strategies; the full ALL set
        // additionally carries SmAd, which the factory rejects without
        // a predictor (covered below).
        for kind in StrategyKind::TABLE {
            let s = make_strategy(kind, None).unwrap();
            assert_eq!(s.kind(), kind);
        }
        assert_eq!(StrategyKind::ALL.len(), StrategyKind::TABLE.len() + 1);
        for kind in StrategyKind::ALL {
            let s = make_strategy(kind, Some(Box::new(|_, _| (1.0, 2.0)))).unwrap();
            assert_eq!(s.kind(), kind, "ALL must build with a predictor supplied");
        }
    }

    #[test]
    fn adaptive_requires_predictor() {
        let err = make_strategy(StrategyKind::SmAd, None).unwrap_err();
        assert!(
            err.to_string().contains("SmAd requires a predictor"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn adaptive_builds_with_predictor() {
        let s = make_strategy(StrategyKind::SmAd, Some(Box::new(|_, _| (1.0, 2.0))))
            .unwrap();
        assert_eq!(s.kind(), StrategyKind::SmAd);
    }
}
