//! The four fixed replication strategies of the paper (Table 1), driven
//! against a replica-group [`Fabric`] (one backup reproduces the paper;
//! N backups fan out with the group's ack policy at durability points).
//!
//! Every strategy's verbs flow through the fabric's staged WQE pipeline
//! (see [`crate::net::wqe`]): data verbs may be batched behind one
//! doorbell, and every fence a strategy issues — `rcommit`, `rofence`,
//! `rdfence`, the sentinel read — is a flush point, so batching never
//! reorders a strategy's writes across its ordering or durability
//! boundaries. SM-DD's ordering point is deliberately *not* a flush: its
//! single shared QP issues staged writes in program order anyway, so the
//! epoch boundary needs no doorbell of its own.

use super::Strategy;
use crate::config::StrategyKind;
use crate::net::{Fabric, WriteMeta};
use crate::sim::ThreadClock;

/// NO-SM: local persistence only (hypothetical performance upper bound).
#[derive(Clone, Copy, Debug, Default)]
pub struct NoSm;

impl Strategy for NoSm {
    fn kind(&self) -> StrategyKind {
        StrategyKind::NoSm
    }
    fn on_clwb(&mut self, _f: &mut Fabric, _t: &mut ThreadClock, _m: WriteMeta) {}
    fn on_ofence(&mut self, _f: &mut Fabric, _t: &mut ThreadClock) {}
    fn on_dfence(&mut self, _f: &mut Fabric, _t: &mut ThreadClock) {}
}

/// SM-RC: one RDMA write per clwb, one blocking `rcommit` per fence —
/// the overloaded-primitive design built on the Talpey-Pinkerton draft.
#[derive(Clone, Copy, Debug, Default)]
pub struct SmRc;

impl Strategy for SmRc {
    fn kind(&self) -> StrategyKind {
        StrategyKind::SmRc
    }
    fn on_clwb(&mut self, f: &mut Fabric, t: &mut ThreadClock, m: WriteMeta) {
        f.post_write(t, m);
    }
    fn on_ofence(&mut self, f: &mut Fabric, t: &mut ThreadClock) {
        // rcommit provides (overloaded) ordering: blocking at every epoch.
        f.rcommit(t);
    }
    fn on_dfence(&mut self, f: &mut Fabric, t: &mut ThreadClock) {
        f.rcommit(t);
    }
}

/// SM-OB (ours): write-through writes + posted `rofence` per epoch + one
/// blocking `rdfence` per transaction — ordering decoupled from durability.
#[derive(Clone, Copy, Debug, Default)]
pub struct SmOb;

impl Strategy for SmOb {
    fn kind(&self) -> StrategyKind {
        StrategyKind::SmOb
    }
    fn on_clwb(&mut self, f: &mut Fabric, t: &mut ThreadClock, m: WriteMeta) {
        f.post_write_wt(t, m);
    }
    fn on_ofence(&mut self, f: &mut Fabric, t: &mut ThreadClock) {
        f.rofence(t); // posted: the thread does not block
    }
    fn on_dfence(&mut self, f: &mut Fabric, t: &mut ThreadClock) {
        f.rdfence(t);
    }
}

/// SM-DD (ours): DDIO disabled on the backups; non-temporal writes through
/// a single QP per backup give implicit program-order persistence;
/// durability is one sentinel RDMA read per backup, acked per policy.
#[derive(Clone, Copy, Debug, Default)]
pub struct SmDd;

impl Strategy for SmDd {
    fn kind(&self) -> StrategyKind {
        StrategyKind::SmDd
    }
    fn on_clwb(&mut self, f: &mut Fabric, t: &mut ThreadClock, m: WriteMeta) {
        f.post_write_nt(t, m);
    }
    fn on_ofence(&mut self, _f: &mut Fabric, _t: &mut ThreadClock) {
        // Implicit ordering: single QP + ordered non-posted PCIe writes.
        // Staged WQEs need no flush here either — the shared QP issues
        // them in program order at the next flush point (the read fence).
    }
    fn on_dfence(&mut self, f: &mut Fabric, t: &mut ThreadClock) {
        f.read_fence(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AckPolicy, Platform, ReplicationConfig};
    use crate::net::{FaultsConfig, OnLoss};

    fn meta(addr: u64, epoch: u32, seq: u64) -> WriteMeta {
        WriteMeta {
            addr,
            val: seq,
            thread: 0,
            txn: 0,
            epoch,
            seq,
        }
    }

    /// Drive one 2-epoch, 1-write-per-epoch transaction through a strategy;
    /// return (thread time, persists on backup 0).
    fn run_txn(s: &mut dyn Strategy) -> (u64, usize) {
        let mut f = Fabric::single(&Platform::default(), true);
        let mut t = ThreadClock::new(0);
        s.on_clwb(&mut f, &mut t, meta(0x40, 0, 0));
        s.on_ofence(&mut f, &mut t);
        s.on_clwb(&mut f, &mut t, meta(0x80, 1, 1));
        s.on_ofence(&mut f, &mut t);
        s.on_dfence(&mut f, &mut t);
        (t.now, f.backup(0).ledger.len())
    }

    #[test]
    fn no_sm_is_free_and_replicates_nothing() {
        let (time, persists) = run_txn(&mut NoSm);
        assert_eq!(time, 0);
        assert_eq!(persists, 0);
    }

    #[test]
    fn all_sm_strategies_replicate_both_writes() {
        for s in [&mut SmRc as &mut dyn Strategy, &mut SmOb, &mut SmDd] {
            let (_, persists) = run_txn(s);
            assert_eq!(persists, 2, "{:?}", s.kind());
        }
    }

    #[test]
    fn rc_pays_per_epoch_round_trips() {
        let (rc_time, _) = run_txn(&mut SmRc);
        let (ob_time, _) = run_txn(&mut SmOb);
        let (dd_time, _) = run_txn(&mut SmDd);
        // RC blocks on rcommit at *every* epoch: ~3 RTTs. OB/DD block once.
        assert!(
            rc_time > 2 * ob_time.min(dd_time),
            "rc={rc_time} ob={ob_time} dd={dd_time}"
        );
        assert!(rc_time >= 3 * 2600, "rc={rc_time}");
    }

    #[test]
    fn ob_and_dd_block_roughly_one_rtt() {
        let (ob_time, _) = run_txn(&mut SmOb);
        let (dd_time, _) = run_txn(&mut SmDd);
        for (name, time) in [("ob", ob_time), ("dd", dd_time)] {
            assert!(
                (2600..2 * 2600).contains(&time),
                "{name}={time} should be ~1 RTT"
            );
        }
    }

    #[test]
    fn epoch_order_preserved_by_every_strategy() {
        for s in [&mut SmRc as &mut dyn Strategy, &mut SmOb, &mut SmDd] {
            let kind = s.kind();
            let mut f = Fabric::single(&Platform::default(), true);
            let mut t = ThreadClock::new(0);
            for epoch in 0..8u32 {
                for wi in 0..2u64 {
                    s.on_clwb(
                        &mut f,
                        &mut t,
                        meta(0x40 * (1 + epoch as u64 * 2 + wi), epoch, epoch as u64 * 2 + wi),
                    );
                }
                s.on_ofence(&mut f, &mut t);
            }
            s.on_dfence(&mut f, &mut t);
            let evs = f.backup(0).ledger.events();
            assert_eq!(evs.len(), 16, "{kind}");
            for a in evs {
                for b in evs {
                    if a.epoch < b.epoch {
                        assert!(
                            a.at <= b.at,
                            "{kind}: epoch {} persisted at {} after epoch {} at {}",
                            a.epoch,
                            a.at,
                            b.epoch,
                            b.at
                        );
                    }
                }
            }
        }
    }

    /// Every strategy's verb pattern must tolerate a dead backup: the
    /// survivors get the full stream, the corpse gets nothing, and the
    /// durability fence still completes under a tolerated loss.
    #[test]
    fn strategies_skip_dead_backups() {
        for s in [&mut SmRc as &mut dyn Strategy, &mut SmOb, &mut SmDd] {
            let kind = s.kind();
            let p = Platform::default();
            let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
            let faults = FaultsConfig::with_plan("kill:2@0", OnLoss::Halt).unwrap();
            let mut f = Fabric::with_faults(&p, &repl, faults, true);
            let mut t = ThreadClock::new(0);
            for epoch in 0..3u32 {
                s.on_clwb(
                    &mut f,
                    &mut t,
                    meta(0x40 * (1 + epoch as u64), epoch, epoch as u64),
                );
                s.on_ofence(&mut f, &mut t);
            }
            s.on_dfence(&mut f, &mut t);
            assert!(f.stall().is_none(), "{kind}: quorum:2 tolerates one loss");
            assert!(t.now >= 2600, "{kind}: fence must still pay the RTT");
            for b in 0..2 {
                assert_eq!(f.backup(b).ledger.len(), 3, "{kind} survivor {b}");
            }
            assert_eq!(f.backup(2).ledger.len(), 0, "{kind}: dead backup wrote");
        }
    }

    /// `all` + `halt`: every strategy's durability point stops at the
    /// kill instead of reporting a weakened ack as durable.
    #[test]
    fn strategies_stall_on_intolerable_loss_under_halt() {
        for s in [&mut SmRc as &mut dyn Strategy, &mut SmOb, &mut SmDd] {
            let kind = s.kind();
            let p = Platform::default();
            let repl = ReplicationConfig::new(2, AckPolicy::All);
            let faults = FaultsConfig::with_plan("kill:0@0", OnLoss::Halt).unwrap();
            let mut f = Fabric::with_faults(&p, &repl, faults, true);
            let mut t = ThreadClock::new(0);
            s.on_clwb(&mut f, &mut t, meta(0x40, 0, 0));
            s.on_ofence(&mut f, &mut t);
            s.on_dfence(&mut f, &mut t);
            let stall = f.stall().unwrap_or_else(|| panic!("{kind}: must stall"));
            assert_eq!(stall.alive, 1, "{kind}");
            assert_eq!(stall.required, 2, "{kind}");
        }
    }

    /// Every strategy's epoch/durability structure must survive
    /// doorbell batching: under the fence flush policy the full write
    /// stream still lands on every backup in per-thread epoch order, and
    /// the fences keep their blocking semantics.
    #[test]
    fn strategies_preserve_epoch_order_under_batching() {
        use crate::net::FlushPolicy;
        for s in [&mut SmRc as &mut dyn Strategy, &mut SmOb, &mut SmDd] {
            let kind = s.kind();
            let p = Platform::default();
            let repl = ReplicationConfig::new(2, AckPolicy::All);
            let mut f = Fabric::new(&p, &repl, true).with_batching(FlushPolicy::Fence);
            let mut t = ThreadClock::new(0);
            for epoch in 0..4u32 {
                for wi in 0..3u64 {
                    let seq = epoch as u64 * 3 + wi;
                    s.on_clwb(&mut f, &mut t, meta(0x40 * (1 + seq), epoch, seq));
                }
                s.on_ofence(&mut f, &mut t);
            }
            s.on_dfence(&mut f, &mut t);
            assert_eq!(f.staged_pending(), 0, "{kind}: dfence must flush");
            for b in 0..2 {
                let evs = f.backup(b).ledger.events();
                assert_eq!(evs.len(), 12, "{kind} backup {b}");
                for a in evs {
                    for c in evs {
                        assert!(
                            a.epoch >= c.epoch || a.at <= c.at,
                            "{kind} backup {b}: epoch order violated under batching"
                        );
                    }
                }
            }
            assert!(
                f.doorbells_total() < f.posted_writes(),
                "{kind}: batching must amortize doorbells"
            );
        }
    }

    #[test]
    fn strategies_replicate_to_full_group() {
        // Every strategy, run against a 3-backup group, must land every
        // write on every backup and preserve per-backup epoch order.
        for s in [&mut SmRc as &mut dyn Strategy, &mut SmOb, &mut SmDd] {
            let kind = s.kind();
            let p = Platform::default();
            let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
            let mut f = Fabric::new(&p, &repl, true);
            let mut t = ThreadClock::new(0);
            for epoch in 0..3u32 {
                s.on_clwb(&mut f, &mut t, meta(0x40 * (1 + epoch as u64), epoch, epoch as u64));
                s.on_ofence(&mut f, &mut t);
            }
            s.on_dfence(&mut f, &mut t);
            for b in 0..3 {
                let evs = f.backup(b).ledger.events();
                assert_eq!(evs.len(), 3, "{kind} backup {b}");
                for w in evs.windows(2) {
                    assert!(
                        w[0].at <= w[1].at || w[0].epoch >= w[1].epoch,
                        "{kind} backup {b}: epoch order violated"
                    );
                }
            }
        }
    }
}
