//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text)
//! and serves them to the coordinator.
//!
//! Two artifacts (built by `make artifacts`, see `python/compile/aot.py`):
//!
//! * `latency_model.hlo.txt` — the L2 strategy-latency model
//!   (`f32[256] e, f32[256] w, f32[16] params -> (f32[256,4] lat,
//!   f32[256,3] slowdown)`), used by the SM-AD adaptive strategy and the
//!   `analytic` CLI command;
//! * `cache_index.hlo.txt` — the L1 complex-addressing set-index kernel
//!   (`u64[1024] addr, u64[8] masks, u64[2] meta -> i32[1024]`), used for
//!   bulk trace annotation and cross-checked against
//!   [`crate::mem::addr::SliceHash`].
//!
//! HLO *text* is the interchange format: jax >= 0.5 serialized protos use
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Python never runs at simulation time — the executables are compiled
//! once here and invoked as pure functions.

use crate::config::Platform;
use crate::replication::{KnobPredictor, Predictor};
use anyhow::{anyhow, Context, Result};

/// Static batch shape of the latency model artifact.
pub const MODEL_N: usize = 256;
/// Static batch shape of the cache-index artifact.
pub const INDEX_N: usize = 1024;

/// Default artifact directory (overridable with PMSM_ARTIFACTS).
pub fn artifacts_dir() -> String {
    std::env::var("PMSM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

fn compile(path: &str) -> Result<xla::PjRtLoadedExecutable> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing {path}: {e:?}"))
        .with_context(|| "did you run `make artifacts`?")?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {path}: {e:?}"))
}

/// The compiled strategy-latency model.
pub struct LatencyModel {
    exe: xla::PjRtLoadedExecutable,
    params: [f32; 16],
}

impl LatencyModel {
    /// Compile the artifact for `platform` on the CPU PJRT client.
    pub fn load(platform: &Platform) -> Result<Self> {
        Self::load_from(
            &format!("{}/latency_model.hlo.txt", artifacts_dir()),
            platform,
        )
    }

    pub fn load_from(path: &str, platform: &Platform) -> Result<Self> {
        Ok(LatencyModel {
            exe: compile(path)?,
            params: platform.to_param_vec(),
        })
    }

    /// Evaluate the model for up to [`MODEL_N`] configurations.
    /// Returns `(latencies[n][4], slowdowns[n][3])` ordered
    /// [NO-SM, SM-RC, SM-OB, SM-DD] / [SM-RC, SM-OB, SM-DD].
    #[allow(clippy::type_complexity)]
    pub fn predict(&self, e: &[f32], w: &[f32]) -> Result<(Vec<[f32; 4]>, Vec<[f32; 3]>)> {
        anyhow::ensure!(e.len() == w.len(), "e/w length mismatch");
        anyhow::ensure!(e.len() <= MODEL_N, "batch exceeds MODEL_N");
        let n = e.len();
        let mut eb = vec![1.0f32; MODEL_N];
        let mut wb = vec![1.0f32; MODEL_N];
        eb[..n].copy_from_slice(e);
        wb[..n].copy_from_slice(w);

        let le = xla::Literal::vec1(&eb);
        let lw = xla::Literal::vec1(&wb);
        let lp = xla::Literal::vec1(&self.params[..]);
        let result = self
            .exe
            .execute::<xla::Literal>(&[le, lw, lp])
            .map_err(|e| anyhow!("executing latency model: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let (lat_lit, slow_lit) = result
            .to_tuple2()
            .map_err(|e| anyhow!("expected 2-tuple: {e:?}"))?;
        let lat_flat = lat_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("lat to_vec: {e:?}"))?;
        let slow_flat = slow_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("slow to_vec: {e:?}"))?;
        let lat = (0..n)
            .map(|i| {
                [
                    lat_flat[i * 4],
                    lat_flat[i * 4 + 1],
                    lat_flat[i * 4 + 2],
                    lat_flat[i * 4 + 3],
                ]
            })
            .collect();
        let slow = (0..n)
            .map(|i| [slow_flat[i * 3], slow_flat[i * 3 + 1], slow_flat[i * 3 + 2]])
            .collect();
        Ok((lat, slow))
    }

    /// Build an SM-AD predictor: ONE batched PJRT call precomputes an
    /// (epochs x writes) latency table; the returned closure looks up the
    /// nearest log-grid cell with zero PJRT work on the decision path.
    pub fn predictor(&self) -> Result<Predictor> {
        // Log-spaced epoch grid x writes 1..=8: 32*8 = 256 = MODEL_N.
        let mut e = Vec::with_capacity(MODEL_N);
        let mut w = Vec::with_capacity(MODEL_N);
        for i in 0..32 {
            let eg = (2f32).powf(i as f32 * 10.0 / 31.0); // 1 .. 1024
            for wi in 1..=8 {
                e.push(eg);
                w.push(wi as f32);
            }
        }
        let (lat, _) = self.predict(&e, &w)?;
        let table: Vec<(f32, f32)> = lat.iter().map(|l| (l[2], l[3])).collect();
        Ok(Box::new(move |eq: f32, wq: f32| {
            let ei = ((eq.max(1.0).log2() * 31.0 / 10.0).round() as usize).min(31);
            let wi = (wq.round() as usize).clamp(1, 8) - 1;
            table[ei * 8 + wi]
        }))
    }

    /// Knob-aware predictor backed by the AOT model: the `(epochs,
    /// writes)` base latency comes from the compiled lookup table (one
    /// batched PJRT call) and the marginal knob terms are the same
    /// closed forms as [`fallback_knob_predictor`]. The extension is
    /// calibrated to vanish at `(backups, quorum, cap) = (1, 1, 1)`, so
    /// the artifact keeps its `f32[16]` signature; the extended
    /// `f32[18]` vector ([`Platform::to_param_vec_ext`], mirrored in
    /// `python/compile/kernels/params.py`) feeds only the margins.
    pub fn knob_predictor(&self, platform: &Platform) -> Result<KnobPredictor> {
        let base = self.predictor()?;
        let p = platform.to_param_vec_ext();
        Ok(Box::new(move |e, w, backups, quorum, cap| {
            let (ob, dd) = base(e, w);
            let (ob_m, dd_m) = knob_margins(&p, e, w, backups, quorum, cap);
            ((ob + ob_m).max(0.0), (dd + dd_m).max(0.0))
        }))
    }
}

/// The compiled cache-index kernel.
pub struct CacheIndexModel {
    exe: xla::PjRtLoadedExecutable,
    masks: [u64; 8],
    meta: [u64; 2],
}

impl CacheIndexModel {
    pub fn load(platform: &Platform) -> Result<Self> {
        Self::load_from(&format!("{}/cache_index.hlo.txt", artifacts_dir()), platform)
    }

    pub fn load_from(path: &str, platform: &Platform) -> Result<Self> {
        let mut masks = [0u64; 8];
        for (i, &m) in platform.slice_masks.iter().take(8).enumerate() {
            masks[i] = m;
        }
        Ok(CacheIndexModel {
            exe: compile(path)?,
            masks,
            meta: [
                platform.llc_sets_per_slice as u64,
                platform.slice_masks.len() as u64,
            ],
        })
    }

    /// Map up to [`INDEX_N`] line addresses to global LLC set indices.
    pub fn cache_sets(&self, addrs: &[u64]) -> Result<Vec<i32>> {
        anyhow::ensure!(addrs.len() <= INDEX_N, "batch exceeds INDEX_N");
        let n = addrs.len();
        let mut ab = vec![0u64; INDEX_N];
        ab[..n].copy_from_slice(addrs);
        let la = xla::Literal::vec1(&ab);
        let lm = xla::Literal::vec1(&self.masks[..]);
        let lmeta = xla::Literal::vec1(&self.meta[..]);
        let result = self
            .exe
            .execute::<xla::Literal>(&[la, lm, lmeta])
            .map_err(|e| anyhow!("executing cache index: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("expected 1-tuple: {e:?}"))?;
        let flat = out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(flat[..n].to_vec())
    }
}

/// Legacy closed-form OB/DD latency at the calibration baseline (one
/// backup, quorum 1, eager posting) — mirrors the python `ref.py`
/// formulas; kept in sync via the pjrt_model integration test.
fn closed_form_base(p: &[f32; 18], e: f32, w: f32) -> (f32, f32) {
    let (rtt, gap, nqp) = (p[0], p[1], p[2]);
    let (llc_mc, mc_pm) = (p[4], p[5]);
    let (store, flush, sfence) = (p[7], p[8], p[9]);
    let (banks, ob_barrier) = (p[10], p[11]);
    let (qp_depth, nt_serial, ddio_lines) = (p[12], p[13], p[14]);
    let n = e * w;
    let local_epoch = w * (store + flush) + sfence + w * llc_mc;
    let ob_issue = n * (gap / nqp) + e * (gap / nqp + ob_barrier);
    let ob_drain = n * (mc_pm / banks);
    let ob_overflow = (n - ddio_lines).max(0.0) * (mc_pm / banks);
    let lat_ob = ob_issue.max(e * local_epoch).max(ob_drain) + ob_overflow + rtt + mc_pm;
    let dd_issue = n * gap;
    let dd_serial = (n - qp_depth).max(0.0) * (nt_serial - gap).max(0.0);
    let lat_dd = (e * local_epoch).max(dd_issue + dd_serial) + rtt;
    (lat_ob, lat_dd)
}

/// Marginal latency of the adaptive knob vector over the calibration
/// baseline — zero at `(backups, quorum, cap) = (1, 1, 1)` by
/// construction, so composing these margins with either base model
/// (closed form or AOT table) reduces exactly to the legacy predictor
/// (mirrors `latency_knob_ref` in python/compile/kernels/ref.py):
///
/// * **fan-out CPU**: each of the `n = e*w` lines charges
///   `b*(stage + doorbell/c)` of primary CPU; the 1-backup eager cost
///   `stage + doorbell` is what the legacy model folds into its
///   calibration, so only the difference enters. Batching (`c > 1`)
///   amortizes the doorbell and is a *saving* even at one backup.
/// * **staging deferral**: lines still staged when the blocking fence
///   flushes serialize their wire issue into the fence wait (one `gap`
///   each). SM-OB's per-epoch ordering fences are flush points, so only
///   the last epoch's residual (`w mod c`) defers; SM-DD has no
///   ordering verbs and stages across the whole transaction
///   (`n mod c`).
/// * **quorum tail**: the fence verb fans out to the backups serially,
///   so blocking on the k-th completion lands ~`(k-1)` issue gaps after
///   the first.
fn knob_margins(p: &[f32; 18], e: f32, w: f32, backups: f32, quorum: f32, cap: f32) -> (f32, f32) {
    let gap = p[1];
    let (doorbell, stage) = (p[16], p[17]);
    let b = backups.max(1.0);
    let k = quorum.clamp(1.0, b);
    let c = cap.max(1.0);
    let n = e * w;
    let fan_cpu = n * (b * (stage + doorbell / c) - (stage + doorbell));
    let q_tail = (k - 1.0) * gap;
    let resid_ob = (w - c * (w / c).floor()) * gap;
    let resid_dd = (n - c * (n / c).floor()) * gap;
    (fan_cpu + resid_ob + q_tail, fan_cpu + resid_dd + q_tail)
}

/// Closed-form fallback predictor (no artifacts needed) — the thin
/// 2-input legacy shim over [`fallback_knob_predictor`], evaluated at
/// the calibration baseline `(backups, quorum, cap) = (1, 1, 1)` where
/// the knob margins vanish, so its outputs are bit-identical to the
/// pre-extension closed form (pinned by the pjrt_model cross-check).
pub fn fallback_predictor(platform: &Platform) -> Predictor {
    let p = platform.to_param_vec_ext();
    Box::new(move |e: f32, w: f32| closed_form_base(&p, e, w))
}

/// Knob-aware closed-form predictor for the adaptive control plane:
/// `(epochs, writes, backups, quorum, batch_cap) -> (lat_ob, lat_dd)`.
/// Base latencies from the legacy closed form plus the marginal knob
/// terms of [`knob_margins`]; reduces exactly to
/// [`fallback_predictor`] at `(1, 1, 1)`.
pub fn fallback_knob_predictor(platform: &Platform) -> KnobPredictor {
    let p = platform.to_param_vec_ext();
    Box::new(move |e, w, backups, quorum, cap| {
        let (ob, dd) = closed_form_base(&p, e, w);
        let (ob_m, dd_m) = knob_margins(&p, e, w, backups, quorum, cap);
        ((ob + ob_m).max(0.0), (dd + dd_m).max(0.0))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_predictor_crossover() {
        let p = Platform::default();
        let f = fallback_predictor(&p);
        let (ob_small, dd_small) = f(4.0, 1.0);
        assert!(dd_small < ob_small, "DD should win at 4-1");
        let (ob_big, dd_big) = f(256.0, 1.0);
        assert!(ob_big < dd_big, "OB should win at 256-1");
    }

    #[test]
    fn knob_predictor_reduces_to_legacy_at_baseline() {
        // The 5-input extension at (backups, quorum, cap) = (1, 1, 1)
        // must be bit-identical to the 2-input legacy shim — the
        // calibration-baseline anchor.
        let p = Platform::default();
        let legacy = fallback_predictor(&p);
        let ext = fallback_knob_predictor(&p);
        for (e, w) in [(1.0, 1.0), (4.0, 1.0), (16.0, 4.0), (256.0, 1.0), (64.0, 8.0)] {
            let (ob0, dd0) = legacy(e, w);
            let (ob1, dd1) = ext(e, w, 1.0, 1.0, 1.0);
            assert_eq!((ob0, dd0), (ob1, dd1), "baseline mismatch at {e}-{w}");
        }
    }

    #[test]
    fn knob_margins_move_in_the_right_directions() {
        let p = Platform::default();
        let ext = fallback_knob_predictor(&p);
        // More backups cost fan-out CPU.
        let (ob1, dd1) = ext(4.0, 1.0, 1.0, 1.0, 1.0);
        let (ob2, dd2) = ext(4.0, 1.0, 2.0, 1.0, 1.0);
        assert!(ob2 > ob1 && dd2 > dd1, "extra backup must not be free");
        // A larger quorum waits longer.
        let (obq1, ddq1) = ext(4.0, 1.0, 2.0, 1.0, 1.0);
        let (obq2, ddq2) = ext(4.0, 1.0, 2.0, 2.0, 1.0);
        assert!(obq2 > obq1 && ddq2 > ddq1, "k=2 must cost a fence tail");
        // Batching amortizes doorbell CPU on bulk writes with no
        // residual (w divisible by cap).
        let (ob_e, _) = ext(1.0, 64.0, 2.0, 1.0, 1.0);
        let (ob_c, _) = ext(1.0, 64.0, 2.0, 1.0, 32.0);
        assert!(ob_c < ob_e, "cap=32 must amortize doorbells on bulk writes");
        // ...but defers wire issue into the fence for small txns whose
        // lines never reach the cap.
        let (_, dd_e) = ext(4.0, 1.0, 2.0, 1.0, 1.0);
        let (_, dd_c) = ext(4.0, 1.0, 2.0, 1.0, 32.0);
        assert!(
            dd_c > dd_e,
            "a 4-line DD txn staged under cap=32 must pay the deferral"
        );
    }

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("PMSM_ARTIFACTS", "/tmp/xyz");
        assert_eq!(artifacts_dir(), "/tmp/xyz");
        std::env::remove_var("PMSM_ARTIFACTS");
        assert_eq!(artifacts_dir(), "artifacts");
    }
}
