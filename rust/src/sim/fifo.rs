//! Work-conserving FIFO resource with a fixed per-item occupancy.
//!
//! Models serial pipelines such as a QP's WQE issue stage (one WQE every
//! `gap` ns) or a PCIe link's header occupancy: an item arriving at `t`
//! starts at `max(t, next_free)` and occupies the resource for its service
//! time.

use crate::Ns;

/// A serial resource processing one item at a time.
#[derive(Clone, Debug, Default)]
pub struct FifoResource {
    next_free: Ns,
}

impl FifoResource {
    pub fn new() -> Self {
        FifoResource { next_free: 0 }
    }

    /// Submit an item arriving at `at` with service time `service`.
    /// Returns `(start, done)`.
    #[inline]
    pub fn submit(&mut self, at: Ns, service: Ns) -> (Ns, Ns) {
        let start = self.next_free.max(at);
        let done = start + service;
        self.next_free = done;
        (start, done)
    }

    /// Time at which the resource next becomes idle.
    #[inline]
    pub fn next_free(&self) -> Ns {
        self.next_free
    }

    /// Force the resource busy until `t` (used for pipeline barriers:
    /// nothing may start before `t`).
    #[inline]
    pub fn stall_until(&mut self, t: Ns) {
        if t > self.next_free {
            self.next_free = t;
        }
    }

    /// Reset to idle at t=0.
    pub fn reset(&mut self) {
        self.next_free = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn back_to_back_items_serialize() {
        let mut f = FifoResource::new();
        let (s1, d1) = f.submit(0, 10);
        let (s2, d2) = f.submit(0, 10);
        assert_eq!((s1, d1), (0, 10));
        assert_eq!((s2, d2), (10, 20));
    }

    #[test]
    fn idle_gap_is_not_reclaimed() {
        let mut f = FifoResource::new();
        f.submit(0, 10);
        let (s, d) = f.submit(100, 5);
        assert_eq!((s, d), (100, 105));
    }

    #[test]
    fn stall_blocks_subsequent_items() {
        let mut f = FifoResource::new();
        f.stall_until(50);
        let (s, _) = f.submit(0, 1);
        assert_eq!(s, 50);
        // stall never rewinds
        f.stall_until(10);
        assert_eq!(f.next_free(), 51);
    }
}
