//! Virtual-time simulation core.
//!
//! The simulator is a *timestamp calculus*: each workload thread carries a
//! virtual clock (ns) that advances as it executes operations, and shared
//! hardware components (QP pipelines, the memory-controller write queue,
//! PCIe links) are modeled as resources that map an arrival time to a
//! (start, completion) pair while maintaining internal availability state.
//!
//! This is equivalent to an event-driven simulation for feed-forward
//! pipelines (every resource is work-conserving FIFO), but runs in O(1)
//! amortized per operation with no event heap on the hot path — a key
//! design decision for the 1M-transaction Transact sweeps (see DESIGN.md
//! §Perf).

pub mod fifo;
pub mod rate;
pub mod server;

pub use fifo::FifoResource;
pub use rate::RateLimiter;
pub use server::BoundedServer;

use crate::Ns;

/// Per-thread virtual clock + scratch identifiers.
#[derive(Clone, Debug)]
pub struct ThreadClock {
    /// Thread id (determines QP assignment and trace attribution).
    pub id: usize,
    /// Current virtual time of this thread (ns).
    pub now: Ns,
    /// Cumulative local busy work (ns): the thread's CPU cost, excluding
    /// blocked waits (`wait_until`). The primary-side busy figure the
    /// doorbell-batching benches track (`fig9_batching`).
    pub busy_ns: Ns,
}

impl ThreadClock {
    pub fn new(id: usize) -> Self {
        ThreadClock {
            id,
            now: 0,
            busy_ns: 0,
        }
    }

    /// Advance the clock by `d` ns of local busy work.
    #[inline]
    pub fn busy(&mut self, d: Ns) {
        self.now += d;
        self.busy_ns += d;
    }

    /// Block until at least `t` (no-op if already past it).
    #[inline]
    pub fn wait_until(&mut self, t: Ns) {
        if t > self.now {
            self.now = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_monotonically() {
        let mut c = ThreadClock::new(0);
        c.busy(10);
        assert_eq!(c.now, 10);
        c.wait_until(5); // in the past: no-op
        assert_eq!(c.now, 10);
        c.wait_until(50);
        assert_eq!(c.now, 50);
    }

    #[test]
    fn busy_excludes_blocked_waits() {
        let mut c = ThreadClock::new(0);
        c.busy(10);
        c.wait_until(1_000);
        c.busy(5);
        assert_eq!(c.now, 1_005);
        assert_eq!(c.busy_ns, 15, "waits must not count as CPU work");
    }
}
