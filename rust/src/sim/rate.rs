//! Time-indexed rate limiter: a shared-resource model that is immune to
//! out-of-order submission.
//!
//! The txn-granular min-clock scheduler submits different threads'
//! operations with virtual timestamps that may interleave arbitrarily
//! within one transaction's span. A naive FIFO (`start = max(at,
//! next_free)`) would serialize an *earlier-timestamped* request behind a
//! *later-timestamped* one submitted first, inflating contention by up to
//! a transaction span. The rate limiter instead accounts capacity in
//! fixed time windows — a request arriving at `at` starts in the first
//! window at/after `at` with spare capacity — so service capacity is
//! conserved regardless of submission order (a fluid-flow approximation
//! of an s-server queue).
//!
//! It also supports *ordering floors* (for `rofence`): a floor registered
//! at arrival time `a` with value `f` forces every request with
//! `at >= a` to start no earlier than `f` — time-filtered, so requests
//! that (in virtual time) preceded the fence are unaffected even if they
//! are submitted later.

use crate::util::FastMap;
use crate::Ns;

/// Windowed-capacity resource with ordering floors.
#[derive(Clone, Debug)]
pub struct RateLimiter {
    /// log2 of the accounting window size (ns).
    window_log2: u32,
    /// Per-request occupancy (ns) — the sustained rate is 1/occ.
    occ: Ns,
    /// Requests admitted per window.
    cap: u32,
    /// window index -> used slots.
    used: FastMap<u64, u32>,
    /// (arrival_from, floor) pairs, sorted by arrival_from.
    floors: Vec<(Ns, Ns)>,
    /// Stats.
    pub admitted: u64,
}

impl RateLimiter {
    /// A limiter sustaining one request per `occ` ns.
    pub fn new(occ: Ns) -> Self {
        let occ = occ.max(1);
        // Window ~= 64 service slots, at least 1024 ns.
        let window = (occ * 64).next_power_of_two().max(1024);
        let window_log2 = window.trailing_zeros();
        RateLimiter {
            window_log2,
            occ,
            cap: (window / occ).max(1) as u32,
            used: FastMap::default(),
            floors: Vec::new(),
            admitted: 0,
        }
    }

    #[inline]
    fn window_of(&self, t: Ns) -> u64 {
        t >> self.window_log2
    }

    /// Largest floor whose `arrival_from <= at` (0 if none).
    fn floor_for(&self, at: Ns) -> Ns {
        // floors is sorted by arrival; floor values are monotone by
        // construction (see add_floor), so take the last applicable one.
        match self.floors.partition_point(|&(a, _)| a <= at) {
            0 => 0,
            i => self.floors[i - 1].1,
        }
    }

    /// Register an ordering floor: requests arriving at/after `arrival`
    /// may not start before `floor`.
    pub fn add_floor(&mut self, arrival: Ns, floor: Ns) {
        let floor = floor.max(self.floor_for(arrival));
        match self.floors.binary_search_by_key(&arrival, |&(a, _)| a) {
            Ok(i) => self.floors[i].1 = self.floors[i].1.max(floor),
            Err(i) => self.floors.insert(i, (arrival, floor)),
        }
        // Make floor values monotone after the insertion point so
        // floor_for can use the last applicable entry.
        let start = self
            .floors
            .binary_search_by_key(&arrival, |&(a, _)| a)
            .unwrap_or_else(|i| i);
        let mut run = 0;
        for i in start..self.floors.len() {
            run = run.max(self.floors[i].1);
            self.floors[i].1 = self.floors[i].1.max(run);
        }
        // Bound memory: keep the 128 most recent fences.
        if self.floors.len() > 128 {
            let cut = self.floors.len() - 128;
            self.floors.drain(..cut);
        }
    }

    /// Admit a request arriving at `at`; returns its start time.
    pub fn submit(&mut self, at: Ns) -> Ns {
        let mut t = at.max(self.floor_for(at));
        loop {
            let w = self.window_of(t);
            let used = self.used.entry(w).or_insert(0);
            if *used < self.cap {
                // Start at the later of `t` and the window's fluid start
                // for its k-th admission.
                let w_start = w << self.window_log2;
                let fluid = w_start + (*used as Ns) * self.occ;
                *used += 1;
                self.admitted += 1;
                // GC old windows occasionally to bound memory.
                if self.used.len() > 4096 {
                    let horizon = w.saturating_sub(2048);
                    self.used.retain(|&k, _| k >= horizon);
                }
                return t.max(fluid);
            }
            // Window full: move to the next one.
            t = (w + 1) << self.window_log2;
        }
    }

    /// Sustained service rate denominator (ns per request).
    pub fn occ(&self) -> Ns {
        self.occ
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncontended_requests_start_immediately() {
        let mut r = RateLimiter::new(100);
        assert_eq!(r.submit(5_000), 5_000);
        assert_eq!(r.submit(50_000), 50_000);
    }

    #[test]
    fn sustained_rate_is_enforced() {
        let mut r = RateLimiter::new(100);
        // 1000 requests all arriving at t=0: last must start >= ~100k.
        let mut last = 0;
        for _ in 0..1000 {
            last = last.max(r.submit(0));
        }
        assert!(last >= 90_000, "last start {last}");
    }

    #[test]
    fn out_of_order_submission_does_not_false_serialize() {
        let mut r = RateLimiter::new(100);
        // A far-future request first...
        assert_eq!(r.submit(1_000_000), 1_000_000);
        // ...must not delay an earlier-timestamped one.
        assert_eq!(r.submit(1_000), 1_000);
    }

    #[test]
    fn floors_apply_only_from_their_arrival() {
        let mut r = RateLimiter::new(100);
        r.add_floor(10_000, 20_000);
        // Before the fence arrival: unaffected.
        assert_eq!(r.submit(5_000), 5_000);
        // After: floored.
        assert!(r.submit(10_000) >= 20_000);
        assert!(r.submit(15_000) >= 20_000);
        // Far after the floor: unaffected.
        assert_eq!(r.submit(30_000), 30_000);
    }

    #[test]
    fn floors_compose_monotonically() {
        let mut r = RateLimiter::new(100);
        r.add_floor(1_000, 5_000);
        r.add_floor(2_000, 4_000); // weaker later floor must not undo
        assert!(r.submit(2_500) >= 5_000);
    }

    #[test]
    fn floor_list_is_bounded() {
        let mut r = RateLimiter::new(100);
        for i in 0..1000 {
            r.add_floor(i * 10, i * 10 + 5);
        }
        assert!(r.floors.len() <= 128);
    }

    #[test]
    fn capacity_is_per_window_not_global_fifo() {
        let mut r = RateLimiter::new(100);
        // Fill one window region around t=0.
        for _ in 0..200 {
            r.submit(0);
        }
        // A request in a far later window is untouched by that backlog.
        assert_eq!(r.submit(10_000_000), 10_000_000);
    }
}
