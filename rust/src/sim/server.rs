//! Bounded multi-bank queueing server — the memory-controller write-queue
//! model (paper §6.1).
//!
//! Semantics: the server holds at most `capacity` in-flight entries. An
//! entry arriving when the queue is full waits until the earliest in-flight
//! entry drains (*back-pressure*: the paper's "once the memory controller's
//! queue is full, the items cannot be inserted either from the LLC or the
//! network"). Draining proceeds on `banks` parallel banks, each taking
//! `service` ns per entry.
//!
//! The in-flight set is a ring of completion times kept sorted by
//! construction (each bank's completion times are monotone, and we track
//! the global earliest via a small binary heap over bank heads — but since
//! banks are few we simply scan).

use crate::Ns;

/// Bounded-capacity, multi-bank FIFO server.
#[derive(Clone, Debug)]
pub struct BoundedServer {
    capacity: usize,
    service: Ns,
    banks: Vec<Ns>,
    /// Completion times of in-flight entries, oldest first (monotone since
    /// admissions are monotone in time and banks are chosen greedily).
    inflight: std::collections::VecDeque<Ns>,
    /// Total entries ever admitted (stats).
    admitted: u64,
    /// Total ns of arrival-side stall caused by a full queue (stats).
    stall_ns: Ns,
}

impl BoundedServer {
    pub fn new(capacity: usize, banks: usize, service: Ns) -> Self {
        assert!(capacity > 0 && banks > 0);
        BoundedServer {
            capacity,
            service,
            banks: vec![0; banks],
            inflight: std::collections::VecDeque::with_capacity(capacity + 1),
            admitted: 0,
            stall_ns: 0,
        }
    }

    /// Admit an entry arriving at `at`.
    /// Returns `(admit, done)`: `admit` is when the entry enters the queue
    /// (== persistence instant under ADR), `done` when it lands in PM.
    pub fn admit(&mut self, at: Ns) -> (Ns, Ns) {
        // Retire drained entries.
        while let Some(&head) = self.inflight.front() {
            if head <= at {
                self.inflight.pop_front();
            } else {
                break;
            }
        }
        // Back-pressure: wait for the head to drain if full.
        let mut admit = at;
        if self.inflight.len() >= self.capacity {
            let head = self.inflight.pop_front().expect("capacity > 0");
            debug_assert!(head >= at);
            self.stall_ns += head - at;
            admit = head;
        }
        // Serve on the earliest-available bank.
        let (bi, &bank_free) = self
            .banks
            .iter()
            .enumerate()
            .min_by_key(|&(_, &t)| t)
            .expect("banks > 0");
        let start = bank_free.max(admit);
        let done = start + self.service;
        self.banks[bi] = done;
        // Keep the inflight deque sorted: done may be smaller than the tail
        // when a faster bank finishes earlier; insert in order.
        let pos = self.inflight.partition_point(|&d| d <= done);
        self.inflight.insert(pos, done);
        self.admitted += 1;
        (admit, done)
    }

    /// Time at which everything currently in flight has drained.
    pub fn drained_at(&self) -> Ns {
        self.inflight.back().copied().unwrap_or(0)
    }

    /// Current occupancy as seen at time `at`.
    pub fn occupancy(&self, at: Ns) -> usize {
        self.inflight.iter().filter(|&&d| d > at).count()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
    pub fn admitted(&self) -> u64 {
        self.admitted
    }
    pub fn stall_ns(&self) -> Ns {
        self.stall_ns
    }
    pub fn service(&self) -> Ns {
        self.service
    }

    pub fn reset(&mut self) {
        self.banks.iter_mut().for_each(|b| *b = 0);
        self.inflight.clear();
        self.admitted = 0;
        self.stall_ns = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_bank_serializes() {
        let mut s = BoundedServer::new(4, 1, 100);
        let (a1, d1) = s.admit(0);
        let (a2, d2) = s.admit(0);
        assert_eq!((a1, d1), (0, 100));
        assert_eq!((a2, d2), (0, 200)); // admitted immediately, drains later
    }

    #[test]
    fn banks_drain_in_parallel() {
        let mut s = BoundedServer::new(8, 4, 100);
        let mut dones = vec![];
        for _ in 0..4 {
            dones.push(s.admit(0).1);
        }
        assert_eq!(dones, vec![100, 100, 100, 100]);
        let (_, d5) = s.admit(0);
        assert_eq!(d5, 200);
    }

    #[test]
    fn backpressure_when_full() {
        let mut s = BoundedServer::new(2, 1, 100);
        s.admit(0); // drains at 100
        s.admit(0); // drains at 200
        let (a3, d3) = s.admit(0); // queue full: waits for head (100)
        assert_eq!(a3, 100);
        assert_eq!(d3, 300);
        assert!(s.stall_ns() >= 100);
    }

    #[test]
    fn queue_empties_over_time() {
        let mut s = BoundedServer::new(2, 1, 100);
        s.admit(0);
        s.admit(0);
        // Arrive long after everything drained: no stall.
        let (a, d) = s.admit(10_000);
        assert_eq!((a, d), (10_000, 10_100));
        assert_eq!(s.occupancy(10_000), 1);
    }

    #[test]
    fn occupancy_never_exceeds_capacity() {
        let mut s = BoundedServer::new(4, 2, 50);
        let mut t = 0;
        for i in 0..1000 {
            let (admit, _) = s.admit(t);
            assert!(s.occupancy(admit) <= 4, "iter {i}");
            t += 7;
        }
    }

    #[test]
    fn drained_at_reflects_tail() {
        let mut s = BoundedServer::new(4, 1, 10);
        s.admit(0);
        s.admit(0);
        assert_eq!(s.drained_at(), 20);
    }
}
