//! PM transaction runtime (paper §3, Figure 1).
//!
//! Storage transactions with undo logging on top of the persistency-model
//! API exposed by [`crate::coordinator::Mirror`]: prepare a log entry,
//! mutate the data structure, invalidate the log — with ordering fences
//! between the steps and a durability fence at commit.

pub mod undo;

pub use undo::{Txn, LOG_ACTIVE, LOG_INVALID};
