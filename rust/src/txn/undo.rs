//! Undo-logging storage transactions (paper Figure 1).
//!
//! Layout of a transaction's log region (one word per line, like all pmsm
//! PM data):
//!
//! ```text
//!   log_base + 0   : status word (LOG_ACTIVE while in flight,
//!                    LOG_INVALID after commit) — doubles as entry count
//!   log_base + 64  : entry 0 address
//!   log_base + 128 : entry 0 old value
//!   log_base + 192 : entry 1 address ...
//! ```
//!
//! Epoch structure per paper Fig. 1: each logged write contributes a
//! "prepare log entry" epoch (log append must persist before the mutation)
//! and a mutation epoch; commit appends a final "invalidate log" epoch and
//! executes the durability fence. This yields `2*writes + 1` epochs per
//! transaction — matching WHISPER's "few writes per epoch, many epochs
//! per transaction" profile.

use crate::coordinator::{Mirror, ThreadCtx};
use crate::replication::TxnShape;
use crate::{Addr, LINE};

/// Log status: transaction in flight (low 32 bits carry the entry count).
pub const LOG_ACTIVE: u64 = 0xAC71_0000_0000_0000;
/// Log status: committed/invalidated.
pub const LOG_INVALID: u64 = 0;

/// An in-flight undo transaction.
pub struct Txn {
    log_base: Addr,
    entries: u32,
    committed: bool,
}

impl Txn {
    /// Begin a transaction whose undo log lives at `log_base` (caller
    /// allocates; one log region per thread is the usual pattern).
    /// `hint` feeds adaptive strategies.
    pub fn begin(
        m: &mut Mirror,
        t: &mut ThreadCtx,
        log_base: Addr,
        hint: Option<TxnShape>,
    ) -> Self {
        m.txn_begin(t, hint);
        // Activate the log. Persisted with the first entry's epoch.
        m.store(t, log_base, LOG_ACTIVE);
        Txn {
            log_base,
            entries: 0,
            committed: false,
        }
    }

    fn entry_addr_slot(&self, i: u32) -> Addr {
        self.log_base + LINE * (1 + 2 * i as u64)
    }
    fn entry_val_slot(&self, i: u32) -> Addr {
        self.log_base + LINE * (2 + 2 * i as u64)
    }

    /// Transactional write: logs the old value (epoch k), then mutates
    /// (epoch k+1 opens; closed by the next log epoch or by commit).
    pub fn write(&mut self, m: &mut Mirror, t: &mut ThreadCtx, addr: Addr, val: u64) {
        assert!(!self.committed, "write after commit");
        let old = m.peek(addr);
        let i = self.entries;
        // --- PrepareLogEntry epoch: entry + refreshed status/count.
        m.store(t, self.entry_addr_slot(i), addr);
        m.clwb(t, self.entry_addr_slot(i));
        m.store(t, self.entry_val_slot(i), old);
        m.clwb(t, self.entry_val_slot(i));
        m.store(t, self.log_base, LOG_ACTIVE | (i + 1) as u64);
        m.clwb(t, self.log_base);
        m.sfence(t); // log must persist before the mutation
        // --- MutateDataStructure epoch.
        m.store(t, addr, val);
        m.clwb(t, addr);
        m.sfence(t); // mutation ordered before the next log append
        self.entries += 1;
    }

    /// Commit: invalidate the log (ordering point), then the durability
    /// fence (paper Fig. 1 "CommitLogEntry; dfence").
    pub fn commit(mut self, m: &mut Mirror, t: &mut ThreadCtx) {
        m.store(t, self.log_base, LOG_INVALID);
        m.clwb(t, self.log_base);
        m.sfence(t);
        m.txn_commit(t);
        self.committed = true;
    }

    /// Number of writes so far.
    pub fn len(&self) -> u32 {
        self.entries
    }
    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }
}

/// Decode a log status word into `Some(entry_count)` when active.
pub fn decode_active(status: u64) -> Option<u32> {
    if status & LOG_ACTIVE == LOG_ACTIVE {
        Some((status & 0xFFFF_FFFF) as u32)
    } else {
        None
    }
}

/// Roll back an active undo log found in a recovered image: returns the
/// (addr, old_value) pairs to restore, newest first (paper §2.1 recovery).
pub fn rollback_plan(
    image: &std::collections::HashMap<Addr, u64>,
    log_base: Addr,
) -> Vec<(Addr, u64)> {
    let status = image.get(&log_base).copied().unwrap_or(LOG_INVALID);
    let Some(count) = decode_active(status) else {
        return Vec::new();
    };
    let mut plan = Vec::new();
    for i in (0..count).rev() {
        let addr_slot = log_base + LINE * (1 + 2 * i as u64);
        let val_slot = log_base + LINE * (2 + 2 * i as u64);
        // An entry may be missing if the crash hit mid-log-append; the
        // status count persists in the same epoch as the entry, so a
        // present count implies present slots — but be defensive.
        if let (Some(&addr), Some(&old)) = (image.get(&addr_slot), image.get(&val_slot)) {
            plan.push((addr, old));
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Platform, StrategyKind};

    fn mirror(kind: StrategyKind) -> Mirror {
        Mirror::new(Platform::default(), kind, true)
    }

    const LOG: Addr = 0x100_0000;
    const DATA: Addr = 0x200_0000;

    #[test]
    fn txn_produces_expected_epoch_count() {
        let mut m = mirror(StrategyKind::NoSm);
        let mut t = ThreadCtx::new(0);
        let mut tx = Txn::begin(&mut m, &mut t, LOG, None);
        tx.write(&mut m, &mut t, DATA, 1);
        tx.write(&mut m, &mut t, DATA + 64, 2);
        tx.commit(&mut m, &mut t);
        // 2 writes x 2 epochs + 1 commit epoch.
        assert_eq!(t.epochs_done, 5);
        assert_eq!(t.txns_done, 1);
        assert_eq!(m.peek(DATA), 1);
        assert_eq!(m.peek(DATA + 64), 2);
        assert_eq!(m.peek(LOG), LOG_INVALID);
    }

    #[test]
    fn log_records_old_values() {
        let mut m = mirror(StrategyKind::NoSm);
        let mut t = ThreadCtx::new(0);
        m.store(&mut t, DATA, 41);
        let mut tx = Txn::begin(&mut m, &mut t, LOG, None);
        tx.write(&mut m, &mut t, DATA, 42);
        // Before commit, the log holds the old value.
        assert_eq!(m.peek(LOG + 64), DATA);
        assert_eq!(m.peek(LOG + 128), 41);
        assert_eq!(decode_active(m.peek(LOG)), Some(1));
        tx.commit(&mut m, &mut t);
        assert_eq!(decode_active(m.peek(LOG)), None);
    }

    #[test]
    fn rollback_plan_restores_in_reverse() {
        let mut img = std::collections::HashMap::new();
        img.insert(LOG, LOG_ACTIVE | 2);
        img.insert(LOG + 64, DATA);
        img.insert(LOG + 128, 10u64);
        img.insert(LOG + 192, DATA); // same addr written twice
        img.insert(LOG + 256, 20u64);
        let plan = rollback_plan(&img, LOG);
        // Newest-first: restore 20 then 10 -> final value 10 (the oldest).
        assert_eq!(plan, vec![(DATA, 20), (DATA, 10)]);
    }

    #[test]
    fn invalid_log_yields_empty_plan() {
        let mut img = std::collections::HashMap::new();
        img.insert(LOG, LOG_INVALID);
        assert!(rollback_plan(&img, LOG).is_empty());
        assert!(rollback_plan(&std::collections::HashMap::new(), LOG).is_empty());
    }

    #[test]
    fn replicated_txn_ledger_has_all_writes() {
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            let mut m = mirror(kind);
            let mut t = ThreadCtx::new(0);
            let mut tx = Txn::begin(&mut m, &mut t, LOG, None);
            tx.write(&mut m, &mut t, DATA, 7);
            tx.commit(&mut m, &mut t);
            // clwbs: entry addr, entry val, status, data, status-invalid = 5
            assert_eq!(m.backup(0).ledger.len(), 5, "{kind:?}");
        }
    }
}
