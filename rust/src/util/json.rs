//! Minimal JSON assembly helpers (no `serde` in the offline registry).
//!
//! Shared by the bench harness's `BENCH_*.json` emission
//! ([`crate::bench`]) and the per-shard replica-group report dump
//! ([`crate::metrics::replica`]), so both speak the same escaping and
//! number rules and stamp the same [`SCHEMA_VERSION`] that CI's
//! `python/check_bench_json.py` asserts on.

/// Schema version stamped into every JSON artifact this crate emits.
/// Bump when a field is renamed/removed or its meaning changes; the CI
/// checker (`python/check_bench_json.py`) pins this value.
pub const SCHEMA_VERSION: u64 = 1;

/// JSON string rendering with escaping (Rust's `{:?}` Debug escapes are
/// not JSON). Returns the quoted, escaped string.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON-safe float rendering (JSON has no NaN/Inf: both collapse to 0).
pub fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "0".to_string()
    }
}

/// An `Option<f64>` as a JSON number or `null`.
pub fn opt_num(x: Option<f64>) -> String {
    match x {
        Some(v) => num(v),
        None => "null".to_string(),
    }
}

/// Render `pairs` (key, pre-rendered value) as a JSON object. Values
/// must already be valid JSON fragments (use [`esc`]/[`num`] for
/// scalars); keys are escaped here.
pub fn obj(pairs: &[(&str, String)]) -> String {
    let body: Vec<String> = pairs
        .iter()
        .map(|(k, v)| format!("{}:{v}", esc(k)))
        .collect();
    format!("{{{}}}", body.join(","))
}

/// Render pre-rendered JSON fragments as a JSON array.
pub fn arr(items: &[String]) -> String {
    format!("[{}]", items.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_are_escaped_for_json_not_rust() {
        assert_eq!(esc("plain"), "\"plain\"");
        assert_eq!(esc("a\"b"), "\"a\\\"b\"");
        assert_eq!(esc("a\\b"), "\"a\\\\b\"");
        assert_eq!(esc("a\nb\tc"), "\"a\\nb\\tc\"");
        // Control chars become \u escapes (valid JSON), not Rust's \u{..}.
        assert_eq!(esc("\u{7}"), "\"\\u0007\"");
        assert!(!esc("\u{7}").contains('{'));
    }

    #[test]
    fn numbers_never_leak_nan_or_inf() {
        assert_eq!(num(1234.5678), "1234.568");
        assert_eq!(num(f64::NAN), "0");
        assert_eq!(num(f64::INFINITY), "0");
        assert_eq!(num(f64::NEG_INFINITY), "0");
        assert_eq!(opt_num(None), "null");
        assert_eq!(opt_num(Some(2.0)), "2.000");
    }

    #[test]
    fn obj_and_arr_compose() {
        let o = obj(&[
            ("name", esc("x\"y")),
            ("n", num(1.0)),
            ("xs", arr(&[num(1.0), num(2.0)])),
        ]);
        assert_eq!(
            o,
            "{\"name\":\"x\\\"y\",\"n\":1.000,\"xs\":[1.000,2.000]}"
        );
        assert_eq!(arr(&[]), "[]");
        assert_eq!(obj(&[]), "{}");
    }
}
