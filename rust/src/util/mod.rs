//! Small self-contained utilities: PRNG, stable hashing, f64 statistics,
//! and hand-rolled JSON assembly.

pub mod json;
pub mod prng;
pub mod stats;

pub use prng::Pcg64;
pub use stats::Summary;

/// Fast single-word hasher for `u64`-keyed maps on the simulator hot path
/// (SipHash's per-lookup cost showed up in the image-map profile —
/// EXPERIMENTS.md §Perf #2). FNV-1a over the 8 key bytes.
#[derive(Clone, Copy, Debug, Default)]
pub struct FnvHasher(u64);

impl std::hash::Hasher for FnvHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut h = if self.0 == 0 { 0xcbf2_9ce4_8422_2325 } else { self.0 };
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        self.0 = h;
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        // Single multiply-xor mix — enough dispersion for line addresses.
        let mut h = self.0 ^ v;
        h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
        h ^= h >> 33;
        self.0 = h;
    }
}

/// `BuildHasher` for [`FnvHasher`].
pub type FnvBuild = std::hash::BuildHasherDefault<FnvHasher>;

/// A `HashMap` keyed by u64-like keys using the fast hasher.
pub type FastMap<K, V> = std::collections::HashMap<K, V, FnvBuild>;

/// FNV-1a 64-bit hash — stable across runs/platforms (used for bucket
/// selection in the persistent hashmap and for deterministic key spreads).
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a over a u64 key.
#[inline]
pub fn fnv1a_u64(v: u64) -> u64 {
    fnv1a(&v.to_le_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_ne!(fnv1a_u64(1), fnv1a_u64(2));
    }
}
