//! PCG-XSL-RR 128/64 pseudo-random number generator.
//!
//! The offline crate set only provides `rand_core` without any generator
//! implementations, so the generator itself is implemented here. PCG64 is
//! small, fast, statistically strong, and — critically for the simulator —
//! fully deterministic and seedable, so every experiment is reproducible
//! from its config seed.

/// PCG-XSL-RR 128/64 (the algorithm behind `rand_pcg::Pcg64`).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xa02b_df8f_2cc8_57b7)
    }

    /// Create a generator with an explicit stream (odd increment derived).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut pcg = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        pcg.next_u64();
        pcg.state = pcg.state.wrapping_add(seed as u128);
        pcg.next_u64();
        pcg
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, bound)` (Lemire rejection-free multiply-shift with
    /// a correction loop for exactness).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        // Multiply-shift with rejection to remove modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let m = (r as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.next_below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Zipfian-distributed value in `[0, n)` with skew `theta` (YCSB-style,
    /// Gray et al. approximation). The zeta partial sums are memoized per
    /// (n, theta) — recomputing the 10^4-term series per draw dominated
    /// the YCSB driver's profile (EXPERIMENTS.md §Perf #1).
    pub fn zipf(&mut self, n: u64, theta: f64) -> u64 {
        debug_assert!(n > 0);
        let zetan = zeta_cached(n, theta);
        let zeta2 = zeta_cached(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        let u = self.next_f64();
        let uz = u * zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(theta) {
            return 1;
        }
        ((n as f64) * (eta * u - eta + 1.0).powf(alpha)) as u64 % n
    }
}

thread_local! {
    static ZETA_CACHE: std::cell::RefCell<std::collections::HashMap<(u64, u64), f64>> =
        std::cell::RefCell::new(std::collections::HashMap::new());
}

/// Memoized zeta partial sum (theta keyed by bit pattern).
fn zeta_cached(n: u64, theta: f64) -> f64 {
    ZETA_CACHE.with(|c| {
        *c.borrow_mut()
            .entry((n, theta.to_bits()))
            .or_insert_with(|| zeta_approx(n, theta))
    })
}

/// Riemann zeta partial-sum approximation (exact below 10_000 terms, Euler–
/// Maclaurin style tail beyond — adequate for workload skew generation).
fn zeta_approx(n: u64, theta: f64) -> f64 {
    let exact = n.min(10_000);
    let mut z = 0.0;
    for i in 1..=exact {
        z += 1.0 / (i as f64).powf(theta);
    }
    if n > exact {
        // integral tail: ∫ x^-theta dx from `exact` to `n`
        z += ((n as f64).powf(1.0 - theta) - (exact as f64).powf(1.0 - theta))
            / (1.0 - theta);
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg64::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(9);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Pcg64::new(3);
        let mut buckets = [0u32; 10];
        for _ in 0..100_000 {
            buckets[r.next_below(10) as usize] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket count {b}");
        }
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Pcg64::new(5);
        let n = 1000;
        let mut head = 0u32;
        for _ in 0..10_000 {
            let v = r.zipf(n, 0.99);
            assert!(v < n);
            if v < 10 {
                head += 1;
            }
        }
        // With theta=0.99 the top-10 keys should absorb a large fraction.
        assert!(head > 2_000, "zipf head mass too small: {head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
