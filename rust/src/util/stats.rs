//! Scalar statistics helpers shared by metrics and the bench harness.

/// Streaming summary of a sequence of f64 samples (Welford variance).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Geometric mean of a slice (used for WHISPER summary rows).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Percentile from an unsorted sample (copies + sorts; fine off hot path).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.min() - 1.0).abs() < 1e-12);
        assert!((s.max() - 4.0).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn geomean_and_percentile() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }
}
