//! Workload suite: the Transact microbenchmark (paper §7.1) and the
//! SM-extended WHISPER applications (paper §7.2).

pub mod transact;
pub mod whisper;

pub use transact::{
    run_append_on, run_transact, run_transact_coalesced, run_transact_concurrent,
    run_transact_sharded, run_transact_with, AppendConfig, TransactConfig,
};
pub use whisper::{run_whisper, run_whisper_with, WhisperApp, WhisperConfig};
