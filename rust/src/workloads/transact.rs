//! Transact — the configurable transaction microbenchmark (paper §7.1).
//!
//! Executes `txns` transactions, each with a configurable number of epochs
//! per transaction and writes per epoch; write addresses are chosen
//! uniformly at random from a working set (the paper: "the addresses of
//! writes within a transaction are randomly chosen"). Ranges mirror the
//! paper: writes/epoch in [1..8], epochs/txn in [1..256].

use crate::config::{Platform, ReplicationConfig, StrategyKind};
use crate::coordinator::sched::{run_threads, RunOutcome, TxnSource};
use crate::coordinator::Mirror;
use crate::replication::{Predictor, TxnShape};
use crate::util::Pcg64;
use crate::{Addr, LINE};
use anyhow::Result;

/// Transact configuration.
#[derive(Clone, Copy, Debug)]
pub struct TransactConfig {
    pub epochs: u32,
    pub writes: u32,
    pub txns: u64,
    pub threads: usize,
    pub seed: u64,
    /// Working-set lines per thread (paper-scale LLC pressure).
    pub working_set: u64,
}

impl Default for TransactConfig {
    fn default() -> Self {
        TransactConfig {
            epochs: 4,
            writes: 1,
            txns: 10_000,
            threads: 1,
            seed: 42,
            working_set: 1 << 16, // 64K lines = 4 MiB per thread
        }
    }
}

fn transact_source(cfg: TransactConfig, thread: usize) -> Box<dyn TxnSource> {
    let mut rng = Pcg64::with_stream(cfg.seed, thread as u64);
    let base: Addr = 0x4000_0000_0000 + (thread as Addr) * 0x1_0000_0000;
    let mut done = 0u64;
    let hint = TxnShape {
        epochs: cfg.epochs as f32,
        writes: cfg.writes as f32,
    };
    Box::new(move |m: &mut Mirror, t: &mut crate::coordinator::ThreadCtx| {
        if done >= cfg.txns {
            return false;
        }
        m.txn_begin(t, Some(hint));
        for _ in 0..cfg.epochs {
            for _ in 0..cfg.writes {
                let addr = base + rng.next_below(cfg.working_set) * LINE;
                m.store(t, addr, done);
                m.clwb(t, addr);
            }
            m.sfence(t);
        }
        m.txn_commit(t);
        done += 1;
        true
    })
}

/// Run Transact under `kind` and return the outcome (single backup, the
/// paper's topology).
pub fn run_transact(plat: &Platform, kind: StrategyKind, cfg: TransactConfig) -> RunOutcome {
    let mut mirror = Mirror::new(plat.clone(), kind, false);
    run_transact_on(&mut mirror, cfg)
}

/// Run Transact with the adaptive strategy wired to `predictor`
/// (single backup).
pub fn run_transact_adaptive(
    plat: &Platform,
    predictor: Predictor,
    cfg: TransactConfig,
) -> RunOutcome {
    let mut mirror =
        Mirror::with_predictor(plat.clone(), StrategyKind::SmAd, predictor, false);
    run_transact_on(&mut mirror, cfg)
}

/// Run Transact against an N-way replica group. Pass a predictor when
/// `kind` is `SmAd`; fails on an invalid replication config.
pub fn run_transact_with(
    plat: &Platform,
    kind: StrategyKind,
    predictor: Option<Predictor>,
    repl: ReplicationConfig,
    cfg: TransactConfig,
) -> Result<RunOutcome> {
    let mut mirror = Mirror::try_build(plat.clone(), kind, predictor, repl, false)?;
    Ok(run_transact_on(&mut mirror, cfg))
}

/// Run Transact against a replica group under a fault plan (runtime
/// backup kills/rejoins — see [`crate::net::faults`]). A halt-mode run
/// that loses more backups than the ack policy tolerates stops at the
/// kill point and reports it in [`RunOutcome::stalled`].
pub fn run_transact_faulted(
    plat: &Platform,
    kind: StrategyKind,
    repl: ReplicationConfig,
    faults: crate::net::FaultsConfig,
    cfg: TransactConfig,
) -> Result<RunOutcome> {
    let mut mirror = Mirror::try_build_faulted(plat.clone(), kind, None, repl, faults, false)?;
    Ok(run_transact_on(&mut mirror, cfg))
}

/// Run Transact against an N-way replica group with the staged WQE
/// pipeline under `batching` (see [`crate::net::wqe`]; `eager`
/// reproduces the unbatched path bit-exactly). Fails on an invalid
/// replication config.
pub fn run_transact_batched(
    plat: &Platform,
    kind: StrategyKind,
    repl: ReplicationConfig,
    batching: crate::net::FlushPolicy,
    cfg: TransactConfig,
) -> Result<RunOutcome> {
    let mut mirror = Mirror::try_build(plat.clone(), kind, None, repl, false)?;
    mirror.set_batching(batching);
    Ok(run_transact_on(&mut mirror, cfg))
}

/// Run Transact with the staged pipeline under `batching` AND the
/// flush-time coalescer under `mode` (see
/// [`crate::net::wqe::CoalesceMode`]). Fails on an invalid replication
/// config or a coalescing mode paired with an eager flush policy.
pub fn run_transact_coalesced(
    plat: &Platform,
    kind: StrategyKind,
    repl: ReplicationConfig,
    batching: crate::net::FlushPolicy,
    mode: crate::net::CoalesceMode,
    cfg: TransactConfig,
) -> Result<RunOutcome> {
    crate::net::CoalescingConfig::new(mode).validate_with(batching)?;
    let mut mirror = Mirror::try_build(plat.clone(), kind, None, repl, false)?;
    mirror.set_batching(batching);
    mirror.set_coalescing(mode);
    Ok(run_transact_on(&mut mirror, cfg))
}

/// Run Transact under the concurrent-primary model: per-shard commit
/// pipelines plus cross-thread group fencing (see
/// [`crate::coordinator::pipeline`] and the group-fence window on
/// [`crate::net::Fabric`]). The default config is the serial anchor —
/// event-for-event the plain group path. Fails on an invalid
/// replication or concurrency config.
pub fn run_transact_concurrent(
    plat: &Platform,
    kind: StrategyKind,
    repl: ReplicationConfig,
    conc: crate::coordinator::ConcurrencyConfig,
    cfg: TransactConfig,
) -> Result<RunOutcome> {
    conc.validate()?;
    let mut mirror = Mirror::try_build(plat.clone(), kind, None, repl, false)?;
    mirror.set_concurrency(conc);
    Ok(run_transact_on(&mut mirror, cfg))
}

/// Run Transact against `sharding.shards` independent replica groups
/// partitioning the PM line-address space (see
/// [`crate::coordinator::shard`]); each shard gets the `repl` group
/// shape. Fails on an invalid replication or sharding config.
pub fn run_transact_sharded(
    plat: &Platform,
    kind: StrategyKind,
    repl: ReplicationConfig,
    sharding: crate::coordinator::ShardingConfig,
    cfg: TransactConfig,
) -> Result<RunOutcome> {
    let mut mirror = Mirror::try_build_sharded(
        plat.clone(),
        kind,
        None,
        repl,
        crate::net::FaultsConfig::default(),
        sharding,
        false,
    )?;
    Ok(run_transact_on(&mut mirror, cfg))
}

/// Run Transact on a caller-built mirror (exposes the fabric for
/// replica-group metrics afterwards).
pub fn run_transact_on(mirror: &mut Mirror, cfg: TransactConfig) -> RunOutcome {
    let mut sources: Vec<Box<dyn TxnSource>> = (0..cfg.threads)
        .map(|i| transact_source(cfg, i))
        .collect();
    run_threads(mirror, &mut sources)
}

/// Locality-heavy Transact variant: each epoch rewrites a hot header
/// line `rewrites` times (same line, same epoch — the write-combining
/// target) and then appends `writes` address-contiguous lines advancing
/// through a per-thread region (the scatter-gather target) — the
/// log-append-plus-header shape real PM logs produce, and the workload
/// `fig10_coalescing` sweeps. Deterministic; no RNG.
#[derive(Clone, Copy, Debug)]
pub struct AppendConfig {
    pub epochs: u32,
    /// Contiguous lines appended per epoch.
    pub writes: u32,
    /// Hot header-line rewrites per epoch.
    pub rewrites: u32,
    pub txns: u64,
    pub threads: usize,
}

impl Default for AppendConfig {
    fn default() -> Self {
        AppendConfig {
            epochs: 2,
            writes: 8,
            rewrites: 2,
            txns: 1_000,
            threads: 1,
        }
    }
}

fn append_source(cfg: AppendConfig, thread: usize) -> Box<dyn TxnSource> {
    let base: Addr = 0x6000_0000_0000 + thread as Addr * 0x1_0000_0000;
    let header: Addr = base; // the hot line
    let mut cursor: Addr = base + LINE; // append frontier
    let mut done = 0u64;
    Box::new(move |m: &mut Mirror, t: &mut crate::coordinator::ThreadCtx| {
        if done >= cfg.txns {
            return false;
        }
        m.txn_begin(t, None);
        for _ in 0..cfg.epochs {
            for r in 0..cfg.rewrites {
                // The header tracks the frontier (last writer wins).
                m.store(t, header, cursor + r as Addr);
                m.clwb(t, header);
            }
            for _ in 0..cfg.writes {
                m.store(t, cursor, done);
                m.clwb(t, cursor);
                cursor += LINE;
            }
            m.sfence(t);
        }
        m.txn_commit(t);
        done += 1;
        true
    })
}

/// Run the append workload on a caller-built mirror (set batching /
/// coalescing on it first).
pub fn run_append_on(mirror: &mut Mirror, cfg: AppendConfig) -> RunOutcome {
    let mut sources: Vec<Box<dyn TxnSource>> = (0..cfg.threads.max(1))
        .map(|i| append_source(cfg, i))
        .collect();
    run_threads(mirror, &mut sources)
}

/// One phase of a phase-mixed Transact run: `txns` transactions of
/// shape `epochs` x `writes`. The adaptive bench (`fig14_adaptive`)
/// drives the controller through distinct per-class regimes by chaining
/// phases; each transaction carries its phase's [`TxnShape`] hint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Phase {
    pub epochs: u32,
    pub writes: u32,
    pub txns: u64,
}

fn phased_source(phases: Vec<Phase>, seed: u64, thread: usize) -> Box<dyn TxnSource> {
    let mut rng = Pcg64::with_stream(seed, thread as u64);
    let base: Addr = 0x7000_0000_0000 + (thread as Addr) * 0x1_0000_0000;
    let working_set: u64 = 1 << 16;
    let mut phase = 0usize;
    let mut done_in_phase = 0u64;
    let mut val = 0u64;
    Box::new(move |m: &mut Mirror, t: &mut crate::coordinator::ThreadCtx| {
        while phase < phases.len() && done_in_phase >= phases[phase].txns {
            phase += 1;
            done_in_phase = 0;
        }
        let Some(p) = phases.get(phase).copied() else {
            return false;
        };
        let hint = TxnShape {
            epochs: p.epochs as f32,
            writes: p.writes as f32,
        };
        m.txn_begin(t, Some(hint));
        for _ in 0..p.epochs {
            for _ in 0..p.writes {
                let addr = base + rng.next_below(working_set) * LINE;
                m.store(t, addr, val);
                m.clwb(t, addr);
            }
            m.sfence(t);
        }
        m.txn_commit(t);
        val += 1;
        done_in_phase += 1;
        true
    })
}

/// Run a phase-mixed Transact workload on a caller-built mirror: each
/// thread executes every phase in order (phase boundaries are
/// per-thread, not barriers).
pub fn run_phased_on(
    mirror: &mut Mirror,
    phases: &[Phase],
    threads: usize,
    seed: u64,
) -> RunOutcome {
    let mut sources: Vec<Box<dyn TxnSource>> = (0..threads.max(1))
        .map(|i| phased_source(phases.to_vec(), seed, i))
        .collect();
    run_threads(mirror, &mut sources)
}

/// Slowdown of `kind` over NO-SM for one Transact configuration
/// (a single Figure-4 cell).
pub fn slowdown(plat: &Platform, kind: StrategyKind, cfg: TransactConfig) -> f64 {
    let base = run_transact(plat, StrategyKind::NoSm, cfg);
    let sm = run_transact(plat, kind, cfg);
    sm.makespan as f64 / base.makespan.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(epochs: u32, writes: u32) -> TransactConfig {
        TransactConfig {
            epochs,
            writes,
            txns: 200,
            ..Default::default()
        }
    }

    #[test]
    fn counts_match_configuration() {
        let out = run_transact(&Platform::default(), StrategyKind::NoSm, small(4, 2));
        assert_eq!(out.txns, 200);
        assert_eq!(out.epochs, 800);
        assert_eq!(out.writes, 1600);
        assert_eq!(out.epochs_per_txn(), 4.0);
        assert_eq!(out.writes_per_epoch(), 2.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = run_transact(&Platform::default(), StrategyKind::SmOb, small(4, 1));
        let b = run_transact(&Platform::default(), StrategyKind::SmOb, small(4, 1));
        assert_eq!(a.makespan, b.makespan);
    }

    #[test]
    fn rc_slowdown_in_paper_band_for_4_1() {
        // Paper Figure 4: SM-RC slowdowns range ~20x-55x.
        let s = slowdown(&Platform::default(), StrategyKind::SmRc, small(4, 1));
        assert!((15.0..80.0).contains(&s), "SM-RC 4-1 slowdown {s}");
    }

    #[test]
    fn ob_dd_beat_rc() {
        let cfg = small(4, 1);
        let p = Platform::default();
        let rc = slowdown(&p, StrategyKind::SmRc, cfg);
        let ob = slowdown(&p, StrategyKind::SmOb, cfg);
        let dd = slowdown(&p, StrategyKind::SmDd, cfg);
        assert!(rc / ob > 2.0, "rc={rc} ob={ob}");
        assert!(rc / dd > 2.0, "rc={rc} dd={dd}");
    }

    #[test]
    fn dd_wins_small_ob_wins_large_w1() {
        // Paper Figure-4 crossover: DD better at few epochs/txn, OB at many.
        let p = Platform::default();
        let dd_small = slowdown(&p, StrategyKind::SmDd, small(4, 1));
        let ob_small = slowdown(&p, StrategyKind::SmOb, small(4, 1));
        assert!(
            dd_small <= ob_small * 1.05,
            "DD should win small txns: dd={dd_small} ob={ob_small}"
        );
        let cfg_big = TransactConfig {
            epochs: 256,
            writes: 1,
            txns: 30,
            ..Default::default()
        };
        let dd_big = slowdown(&p, StrategyKind::SmDd, cfg_big);
        let ob_big = slowdown(&p, StrategyKind::SmOb, cfg_big);
        assert!(
            ob_big < dd_big,
            "OB should win big txns: ob={ob_big} dd={dd_big}"
        );
    }

    #[test]
    fn replica_groups_scale_cost_monotonically() {
        use crate::config::{AckPolicy, ReplicationConfig};
        let p = Platform::default();
        let cfg = small(4, 1);
        // backups=1 + all through the group path must equal the classic
        // single-backup entry point (the regression anchor end-to-end).
        let single = run_transact(&p, StrategyKind::SmOb, cfg).makespan;
        let group1 = run_transact_with(
            &p,
            StrategyKind::SmOb,
            None,
            ReplicationConfig::default(),
            cfg,
        )
        .unwrap()
        .makespan;
        assert_eq!(single, group1, "fabric(1, all) must reproduce single-backup");
        // More backups never make an All-policy run faster.
        let group3 = run_transact_with(
            &p,
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(3, AckPolicy::All),
            cfg,
        )
        .unwrap()
        .makespan;
        assert!(group3 >= group1, "3 backups {group3} < 1 backup {group1}");
        // Quorum relaxes the fence relative to All on the same group.
        let quorum3 = run_transact_with(
            &p,
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(3, AckPolicy::Quorum(2)),
            cfg,
        )
        .unwrap()
        .makespan;
        assert!(quorum3 <= group3, "quorum {quorum3} > all {group3}");
        // Invalid shapes surface as errors, not panics.
        assert!(run_transact_with(
            &p,
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(2, AckPolicy::Quorum(3)),
            cfg,
        )
        .is_err());
    }

    #[test]
    fn faulted_run_degrades_or_stalls() {
        use crate::config::{AckPolicy, ReplicationConfig};
        use crate::net::{FaultsConfig, OnLoss};
        let p = Platform::default();
        let cfg = small(4, 1);
        let repl = ReplicationConfig::new(3, AckPolicy::All);
        // Empty plan: identical to the fault-free group path (anchor).
        let clean = run_transact_with(&p, StrategyKind::SmOb, None, repl, cfg)
            .unwrap()
            .makespan;
        let empty = run_transact_faulted(
            &p,
            StrategyKind::SmOb,
            repl,
            FaultsConfig::default(),
            cfg,
        )
        .unwrap();
        assert_eq!(empty.makespan, clean, "empty fault plan must be a no-op");
        assert!(empty.stalled.is_none());
        // Kill one backup mid-run: degrade completes, halt stops early.
        let kill_at = clean / 2;
        let mk = |mode| FaultsConfig::with_plan(&format!("kill:1@{kill_at}"), mode).unwrap();
        let degraded = run_transact_faulted(
            &p,
            StrategyKind::SmOb,
            repl,
            mk(OnLoss::Degrade),
            cfg,
        )
        .unwrap();
        assert!(degraded.stalled.is_none());
        assert_eq!(degraded.txns, cfg.txns);
        assert!(degraded.per_backup_dead_ns[1] > 0);
        let halted = run_transact_faulted(
            &p,
            StrategyKind::SmOb,
            repl,
            mk(OnLoss::Halt),
            cfg,
        )
        .unwrap();
        let stall = halted.stalled.expect("all + halt must stall");
        assert!(stall.at >= kill_at);
        assert!(halted.txns < cfg.txns, "halted run must stop early");
    }

    #[test]
    fn append_workload_is_locality_heavy_and_coalesces() {
        use crate::config::{AckPolicy, ReplicationConfig};
        use crate::net::{CoalesceMode, FlushPolicy};
        let p = Platform::default();
        let cfg = AppendConfig {
            epochs: 2,
            writes: 8,
            rewrites: 2,
            txns: 20,
            threads: 1,
        };
        let run = |mode: CoalesceMode| {
            let mut m = Mirror::with_replication(
                p.clone(),
                StrategyKind::SmOb,
                ReplicationConfig::new(2, AckPolicy::All),
                false,
            )
            .unwrap();
            m.set_batching(FlushPolicy::Fence);
            m.set_coalescing(mode);
            run_append_on(&mut m, cfg)
        };
        let none = run(CoalesceMode::None);
        let full = run(CoalesceMode::Full);
        assert_eq!(none.txns, 20);
        // (8 appends + 2 rewrites) x 2 epochs x 20 txns x 2 backups.
        assert_eq!(none.posted_wqes, 20 * 2 * 10 * 2);
        assert_eq!(none.wire_wqes, none.posted_wqes);
        assert_eq!(full.txns, none.txns);
        assert!(full.wire_wqes < none.wire_wqes, "appends must merge");
        assert!(full.combined_writes > 0, "header rewrites must combine");
        assert!(full.mean_span() > 1.0);
        // The coalesced-runner convenience rejects eager pairings.
        assert!(run_transact_coalesced(
            &p,
            StrategyKind::SmOb,
            ReplicationConfig::default(),
            FlushPolicy::Eager,
            CoalesceMode::Sg,
            small(2, 1),
        )
        .is_err());
        // ...and runs clean ones.
        let out = run_transact_coalesced(
            &p,
            StrategyKind::SmOb,
            ReplicationConfig::default(),
            FlushPolicy::Fence,
            CoalesceMode::Full,
            small(2, 1),
        )
        .unwrap();
        assert_eq!(out.txns, 200);
    }

    #[test]
    fn concurrent_runner_piggybacks_and_anchors() {
        use crate::config::ReplicationConfig;
        use crate::coordinator::ConcurrencyConfig;
        let p = Platform::default();
        let cfg = TransactConfig {
            threads: 2,
            txns: 100,
            ..small(4, 1)
        };
        // Default concurrency = the serial anchor, event-for-event.
        let serial =
            run_transact_with(&p, StrategyKind::SmOb, None, ReplicationConfig::default(), cfg)
                .unwrap();
        let anchored = run_transact_concurrent(
            &p,
            StrategyKind::SmOb,
            ReplicationConfig::default(),
            ConcurrencyConfig::default(),
            cfg,
        )
        .unwrap();
        assert_eq!(anchored.makespan, serial.makespan);
        assert_eq!(anchored.busy_ns, serial.busy_ns);
        assert_eq!(anchored.fences_issued, serial.fences_issued);
        assert_eq!(anchored.fence_piggybacks, 0);
        // A group-fence window lets the second thread's commits ride the
        // first's: fewer issued fences, strictly less CPU.
        let grouped = run_transact_concurrent(
            &p,
            StrategyKind::SmOb,
            ReplicationConfig::default(),
            ConcurrencyConfig::new(2, 2_600),
            cfg,
        )
        .unwrap();
        assert!(grouped.fence_piggybacks > 0, "window must piggyback");
        assert!(grouped.fences_issued < serial.fences_issued);
        assert!(grouped.busy_ns < serial.busy_ns, "piggybacks save post cost");
        assert_eq!(
            grouped.fences_issued + grouped.fence_piggybacks,
            serial.fences_issued,
            "every commit still fences — some just share the issue"
        );
        // Invalid shapes surface as errors.
        assert!(run_transact_concurrent(
            &p,
            StrategyKind::SmOb,
            ReplicationConfig::default(),
            ConcurrencyConfig::new(0, 0),
            cfg,
        )
        .is_err());
    }

    #[test]
    fn phased_workload_runs_every_phase_in_order() {
        let p = Platform::default();
        let phases = [
            Phase { epochs: 4, writes: 1, txns: 10 },
            Phase { epochs: 1, writes: 8, txns: 5 },
            Phase { epochs: 16, writes: 2, txns: 3 },
        ];
        let mut m = Mirror::new(p.clone(), StrategyKind::SmOb, false);
        let out = run_phased_on(&mut m, &phases, 1, 42);
        assert_eq!(out.txns, 18, "every phase's txns commit");
        assert_eq!(out.epochs, 4 * 10 + 5 + 16 * 3);
        assert_eq!(out.writes, 4 * 10 + 8 * 5 + 32 * 3);
        // Deterministic per seed.
        let mut m2 = Mirror::new(p, StrategyKind::SmOb, false);
        let out2 = run_phased_on(&mut m2, &phases, 1, 42);
        assert_eq!(out.makespan, out2.makespan);
    }

    #[test]
    fn adaptive_tracks_best_fixed_strategy() {
        let p = Platform::default();
        let cfg = TransactConfig {
            epochs: 256,
            writes: 1,
            txns: 30,
            ..Default::default()
        };
        // Predictor mirrors the closed-form crossover at e=69 (w=1).
        let adapt = run_transact_adaptive(
            &p,
            Box::new(|e, w| {
                let n = e * w;
                let ob = n * 37.5 + e * 112.5 + 2750.0;
                let dd = n * 150.0 + (n - 64.0).max(0.0) * 60.0 + 2600.0;
                (ob, dd)
            }),
            cfg,
        );
        let ob = run_transact(&p, StrategyKind::SmOb, cfg);
        let dd = run_transact(&p, StrategyKind::SmDd, cfg);
        let best = ob.makespan.min(dd.makespan);
        assert!(
            (adapt.makespan as f64) <= best as f64 * 1.10,
            "adaptive {} should track best fixed {}",
            adapt.makespan,
            best
        );
    }
}
