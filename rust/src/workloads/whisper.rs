//! WHISPER applications extended with SM support (paper §7.2).
//!
//! Five applications, each generating its persistency trace from *real*
//! persistent data structures ([`crate::pstore`]):
//!
//! * `ctree`   — inserts/deletes on a persistent crit-bit tree (NVML).
//! * `echo`    — persistent KV store applying batched updates (the
//!               largest epochs/txn in WHISPER, 300+).
//! * `hashmap` — inserts/deletes on a persistent chained hashmap (NVML).
//! * `ycsb`    — zipfian read/update over a mini N-store table.
//! * `tpcc`    — new-order + payment business transactions over N-store.
//!
//! Threads own disjoint structure instances (lock-based concurrency
//! control serializes structure access in WHISPER; partitioning gives the
//! same trace shape) but share the NIC, fabric and backup memory system —
//! so cross-thread QP/barrier/MC contention is fully modeled. Volatile
//! compute between persistent ops reproduces WHISPER's ~5% persistent-
//! write fraction.

use crate::config::{Platform, StrategyKind};
use crate::coordinator::sched::{run_threads, Phased, RunOutcome, TxnSource};
use crate::coordinator::{Mirror, ThreadCtx};
use std::cell::RefCell;
use std::rc::Rc;
use crate::pstore::{log_base_for, CritBitTree, KvStore, NStore, PHashMap, PmHeap};
use crate::replication::TxnShape;
use crate::txn::Txn;
use crate::util::Pcg64;

/// The five WHISPER applications.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WhisperApp {
    Ctree,
    Echo,
    Hashmap,
    Ycsb,
    Tpcc,
}

impl WhisperApp {
    pub const ALL: [WhisperApp; 5] = [
        Self::Ctree,
        Self::Echo,
        Self::Hashmap,
        Self::Ycsb,
        Self::Tpcc,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Self::Ctree => "ctree",
            Self::Echo => "echo",
            Self::Hashmap => "hashmap",
            Self::Ycsb => "ycsb",
            Self::Tpcc => "tpcc",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|a| a.name() == s)
    }
}

impl std::fmt::Display for WhisperApp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// WHISPER run configuration.
#[derive(Clone, Copy, Debug)]
pub struct WhisperConfig {
    pub app: WhisperApp,
    /// Transactions per thread.
    pub ops: u64,
    pub threads: usize,
    pub seed: u64,
}

impl Default for WhisperConfig {
    fn default() -> Self {
        WhisperConfig {
            app: WhisperApp::Ctree,
            ops: 2_000,
            threads: 4,
            seed: 42,
        }
    }
}

// --------------------------------------------------------------- sources

struct CtreeState {
    rng: Pcg64,
    heap: PmHeap,
    tree: CritBitTree,
    log: u64,
    done: u64,
    warm: u64,
}

fn ctree_source(cfg: WhisperConfig, thread: usize) -> Box<dyn TxnSource> {
    let mut heap = PmHeap::new(); // volatile metadata; addresses disjoint
    // Offset each thread's heap into its own area by pre-reserving.
    heap.alloc((thread + 1) << 16);
    let st = Rc::new(RefCell::new(CtreeState {
        rng: Pcg64::with_stream(cfg.seed, thread as u64),
        heap,
        tree: CritBitTree::new(thread as u64 * 4),
        log: log_base_for(thread),
        done: 0,
        warm: 0,
    }));
    let hint = TxnShape { epochs: 15.0, writes: 1.0 };
    let stw = st.clone();
    Box::new(Phased {
        // Warmup: pre-populate ~2048 keys (chunks interleave threads).
        warmup: move |m: &mut Mirror, t: &mut ThreadCtx| {
            let s = &mut *stw.borrow_mut();
            for _ in 0..128 {
                let key = s.rng.next_below(4096);
                s.tree.insert(m, t, &mut s.heap, key, 1, s.log, None);
                s.warm += 1;
            }
            s.warm < 2048
        },
        step: move |m: &mut Mirror, t: &mut ThreadCtx| {
            let s = &mut *st.borrow_mut();
            if s.done >= cfg.ops {
                return false;
            }
            let key = s.rng.next_below(4096);
            // Volatile work: request parsing, key comparison walk, etc.
            m.compute(t, 2200);
            let v = s.done;
            if s.rng.chance(0.6) || s.tree.is_empty() {
                s.tree.insert(m, t, &mut s.heap, key, v, s.log, Some(hint));
            } else {
                s.tree.remove(m, t, &mut s.heap, key, s.log, Some(hint));
            }
            // Read-mostly foreground traffic between updates.
            for _ in 0..3 {
                let k = s.rng.next_below(4096);
                s.tree.get(m, t, k);
                m.compute(t, 600);
            }
            s.done += 1;
            true
        },
    })
}

struct HashmapState {
    rng: Pcg64,
    heap: PmHeap,
    map: PHashMap,
    log: u64,
    done: u64,
    warm: u64,
}

fn hashmap_source(cfg: WhisperConfig, thread: usize) -> Box<dyn TxnSource> {
    let mut heap = PmHeap::new();
    heap.alloc(0x100000 * (thread + 1));
    let map = PHashMap::create(&mut heap, 1024);
    let st = Rc::new(RefCell::new(HashmapState {
        rng: Pcg64::with_stream(cfg.seed ^ 0x4a5_u64, thread as u64),
        heap,
        map,
        log: log_base_for(thread),
        done: 0,
        warm: 0,
    }));
    let hint = TxnShape { epochs: 9.0, writes: 1.0 };
    let stw = st.clone();
    Box::new(Phased {
        warmup: move |m: &mut Mirror, t: &mut ThreadCtx| {
            let s = &mut *stw.borrow_mut();
            for _ in 0..128 {
                let key = s.rng.next_below(8192);
                s.map.put(m, t, &mut s.heap, key, 1, s.log, None);
                s.warm += 1;
            }
            s.warm < 4096
        },
        step: move |m: &mut Mirror, t: &mut ThreadCtx| {
            let s = &mut *st.borrow_mut();
            if s.done >= cfg.ops {
                return false;
            }
            let key = s.rng.next_below(8192);
            m.compute(t, 1600);
            let v = s.done;
            if s.rng.chance(0.6) || s.map.is_empty() {
                s.map.put(m, t, &mut s.heap, key, v, s.log, Some(hint));
            } else {
                s.map.remove(m, t, &mut s.heap, key, s.log, Some(hint));
            }
            for _ in 0..2 {
                let k = s.rng.next_below(8192);
                s.map.get(m, t, k);
                m.compute(t, 500);
            }
            s.done += 1;
            true
        },
    })
}

struct EchoState {
    rng: Pcg64,
    heap: PmHeap,
    kv: KvStore,
    log: u64,
    done: u64,
    warm: u64,
}

fn echo_source(cfg: WhisperConfig, thread: usize) -> Box<dyn TxnSource> {
    let mut heap = PmHeap::new();
    heap.alloc(0x200000 * (thread + 1));
    let kv = KvStore::create(&mut heap, 4096, thread as u64);
    let st = Rc::new(RefCell::new(EchoState {
        rng: Pcg64::with_stream(cfg.seed ^ 0xec0, thread as u64),
        heap,
        kv,
        log: log_base_for(thread),
        done: 0,
        warm: 0,
    }));
    const BATCH: usize = 64; // master applies batched client updates
    let stw = st.clone();
    Box::new(Phased {
        warmup: move |m: &mut Mirror, t: &mut ThreadCtx| {
            let s = &mut *stw.borrow_mut();
            let batch: Vec<(u64, u64)> = (0..BATCH)
                .map(|_| (s.rng.next_below(64 * 1024), 1))
                .collect();
            s.kv.apply_batch(m, t, &mut s.heap, &batch, s.log);
            s.warm += 1;
            s.warm < 4
        },
        step: move |m: &mut Mirror, t: &mut ThreadCtx| {
            let s = &mut *st.borrow_mut();
            if s.done >= cfg.ops {
                return false;
            }
            // Client-side work: accumulate + deduplicate the batch.
            let mut batch = Vec::with_capacity(BATCH);
            for _ in 0..BATCH {
                let k = s.rng.next_below(64 * 1024);
                let v = s.rng.next_u64();
                batch.push((k, v));
                m.compute(t, 900); // request handling per update
            }
            s.kv.apply_batch(m, t, &mut s.heap, &batch, s.log);
            s.done += 1;
            true
        },
    })
}

struct YcsbState {
    rng: Pcg64,
    heap: PmHeap,
    db: NStore,
    table: crate::pstore::nstore::TableId,
    log: u64,
    done: u64,
    loaded: u64,
}

fn ycsb_source(cfg: WhisperConfig, thread: usize) -> Box<dyn TxnSource> {
    let mut heap = PmHeap::new();
    heap.alloc(0x400000 * (thread + 1));
    let mut db = NStore::new();
    let table = db.create_table("usertable", 8);
    let st = Rc::new(RefCell::new(YcsbState {
        rng: Pcg64::with_stream(cfg.seed ^ 0x5c5b, thread as u64),
        heap,
        db,
        table,
        log: log_base_for(thread),
        done: 0,
        loaded: 0,
    }));
    let rows = 4096u64;
    let hint = TxnShape { epochs: 3.0, writes: 1.0 };
    let stw = st.clone();
    Box::new(Phased {
        // Warmup: load the table in 256-row transactions.
        warmup: move |m: &mut Mirror, t: &mut ThreadCtx| {
            let s = &mut *stw.borrow_mut();
            let log = s.log;
            let table = s.table;
            let from = s.loaded;
            let to = (from + 256).min(rows);
            let mut tx = Txn::begin(m, t, log, None);
            for k in from..to {
                let mut row: Vec<u64> = vec![k];
                row.extend((1..8).map(|f| k * 100 + f));
                // Split borrows: db / heap are separate fields.
                let YcsbState { db, heap, .. } = s;
                db.insert(m, t, &mut tx, heap, table, &row);
            }
            tx.commit(m, t);
            s.loaded = to;
            s.loaded < rows
        },
        step: move |m: &mut Mirror, t: &mut ThreadCtx| {
            let s = &mut *st.borrow_mut();
            if s.done >= cfg.ops {
                return false;
            }
            let key = s.rng.zipf(rows, 0.99);
            m.compute(t, 2000); // query parsing/planning
            if s.rng.chance(0.5) {
                // Read: load all fields.
                for f in 0..8 {
                    s.db.select(m, t, s.table, key, f);
                }
            } else {
                // Update: one field under a transaction.
                let log = s.log;
                let table = s.table;
                let field = 1 + (s.rng.next_below(7) as usize);
                let val = s.rng.next_u64();
                let mut tx = Txn::begin(m, t, log, Some(hint));
                s.db.update(m, t, &mut tx, table, key, field, val);
                tx.commit(m, t);
            }
            s.done += 1;
            true
        },
    })
}

struct TpccState {
    rng: Pcg64,
    heap: PmHeap,
    db: NStore,
    orders: crate::pstore::nstore::TableId,
    stock: crate::pstore::nstore::TableId,
    customer: crate::pstore::nstore::TableId,
    district: crate::pstore::nstore::TableId,
    log: u64,
    order_id: u64,
    done: u64,
    loaded: u64,
}

fn tpcc_source(cfg: WhisperConfig, thread: usize) -> Box<dyn TxnSource> {
    let mut heap = PmHeap::new();
    heap.alloc(0x800000 * (thread + 1));
    let mut db = NStore::new();
    let orders = db.create_table("orders", 8);
    let stock = db.create_table("stock", 4);
    let customer = db.create_table("customer", 6);
    let district = db.create_table("district", 4);
    let st = Rc::new(RefCell::new(TpccState {
        rng: Pcg64::with_stream(cfg.seed ^ 0x79cc, thread as u64),
        heap,
        db,
        orders,
        stock,
        customer,
        district,
        log: log_base_for(thread),
        order_id: (thread as u64) << 32,
        done: 0,
        loaded: 0,
    }));
    let n_items = 1024u64;
    let n_cust = 512u64;
    let stw = st.clone();
    Box::new(Phased {
        // Warmup: load stock + customers + district in chunks.
        warmup: move |m: &mut Mirror, t: &mut ThreadCtx| {
            let s = &mut *stw.borrow_mut();
            let log = s.log;
            let from = s.loaded;
            let to = (from + 256).min(n_items + n_cust + 1);
            let mut tx = Txn::begin(m, t, log, None);
            for i in from..to {
                let TpccState { db, heap, stock, customer, district, .. } = s;
                if i < n_items {
                    db.insert(m, t, &mut tx, heap, *stock, &[i, 100, 0, 0]);
                } else if i < n_items + n_cust {
                    let c = i - n_items;
                    db.insert(m, t, &mut tx, heap, *customer, &[c, 1000, 0, 0, 0, 0]);
                } else {
                    db.insert(m, t, &mut tx, heap, *district, &[0, 1, 0, 0]);
                }
            }
            tx.commit(m, t);
            s.loaded = to;
            s.loaded < n_items + n_cust + 1
        },
        step: move |m: &mut Mirror, t: &mut ThreadCtx| {
            let s = &mut *st.borrow_mut();
            if s.done >= cfg.ops {
                return false;
            }
            m.compute(t, 9000); // business logic, item validation
            let log = s.log;
            if s.rng.chance(0.5) {
                // NEW-ORDER: insert an order row + decrement 5 stock
                // levels + bump the district next-order-id.
                s.order_id += 1;
                let order_id = s.order_id;
                let cust = s.rng.next_below(n_cust);
                let items: Vec<u64> =
                    (0..5).map(|_| s.rng.next_below(n_items)).collect();
                let mut tx =
                    Txn::begin(m, t, log, Some(TxnShape { epochs: 29.0, writes: 1.0 }));
                let row = [order_id, cust, 5, 0, 0, 0, 0, 0];
                {
                    let TpccState { db, heap, orders, .. } = s;
                    db.insert(m, t, &mut tx, heap, *orders, &row);
                }
                for &item in &items {
                    let stock = s.stock;
                    let cur = s.db.select(m, t, stock, item, 1).unwrap_or(100);
                    s.db
                        .update(m, t, &mut tx, stock, item, 1, cur.saturating_sub(1));
                }
                let district = s.district;
                let next = s.db.select(m, t, district, 0, 1).unwrap_or(1);
                s.db.update(m, t, &mut tx, district, 0, 1, next + 1);
                tx.commit(m, t);
            } else {
                // PAYMENT: update customer balance + district YTD.
                let cust = s.rng.next_below(n_cust);
                let mut tx =
                    Txn::begin(m, t, log, Some(TxnShape { epochs: 5.0, writes: 1.0 }));
                let customer = s.customer;
                let bal = s.db.select(m, t, customer, cust, 1).unwrap_or(0);
                s.db
                    .update(m, t, &mut tx, customer, cust, 1, bal.saturating_sub(10));
                let district = s.district;
                let ytd = s.db.select(m, t, district, 0, 2).unwrap_or(0);
                s.db.update(m, t, &mut tx, district, 0, 2, ytd + 10);
                tx.commit(m, t);
            }
            s.done += 1;
            true
        },
    })
}

fn make_source(cfg: WhisperConfig, thread: usize) -> Box<dyn TxnSource> {
    match cfg.app {
        WhisperApp::Ctree => ctree_source(cfg, thread),
        WhisperApp::Echo => echo_source(cfg, thread),
        WhisperApp::Hashmap => hashmap_source(cfg, thread),
        WhisperApp::Ycsb => ycsb_source(cfg, thread),
        WhisperApp::Tpcc => tpcc_source(cfg, thread),
    }
}

/// Run a WHISPER app under `kind` (single backup, the paper's topology).
pub fn run_whisper(plat: &Platform, kind: StrategyKind, cfg: WhisperConfig) -> RunOutcome {
    let mut mirror = Mirror::new(plat.clone(), kind, false);
    run_whisper_on(&mut mirror, cfg)
}

/// Run a WHISPER app against an N-way replica group.
pub fn run_whisper_with(
    plat: &Platform,
    kind: StrategyKind,
    repl: crate::config::ReplicationConfig,
    cfg: WhisperConfig,
) -> anyhow::Result<RunOutcome> {
    let mut mirror = Mirror::with_replication(plat.clone(), kind, repl, false)?;
    Ok(run_whisper_on(&mut mirror, cfg))
}

/// Run a WHISPER app on a caller-built mirror.
pub fn run_whisper_on(mirror: &mut Mirror, cfg: WhisperConfig) -> RunOutcome {
    let mut sources: Vec<Box<dyn TxnSource>> = (0..cfg.threads)
        .map(|i| make_source(cfg, i))
        .collect();
    run_threads(mirror, &mut sources)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(app: WhisperApp) -> WhisperConfig {
        WhisperConfig {
            app,
            ops: 60,
            threads: 2,
            seed: 7,
        }
    }

    #[test]
    fn all_apps_run_and_produce_transactions() {
        for app in WhisperApp::ALL {
            let out = run_whisper(&Platform::default(), StrategyKind::NoSm, tiny(app));
            assert!(out.txns > 0, "{app}: no transactions");
            assert!(out.writes > 0, "{app}: no persistent writes");
            assert!(out.makespan > 0, "{app}");
        }
    }

    #[test]
    fn echo_has_largest_epochs_per_txn() {
        let echo = run_whisper(&Platform::default(), StrategyKind::NoSm, tiny(WhisperApp::Echo));
        let hm = run_whisper(
            &Platform::default(),
            StrategyKind::NoSm,
            tiny(WhisperApp::Hashmap),
        );
        assert!(
            echo.epochs_per_txn() > 100.0,
            "echo epochs/txn = {}",
            echo.epochs_per_txn()
        );
        assert!(
            echo.epochs_per_txn() > 5.0 * hm.epochs_per_txn(),
            "echo {} vs hashmap {}",
            echo.epochs_per_txn(),
            hm.epochs_per_txn()
        );
    }

    #[test]
    fn writes_per_epoch_is_low() {
        // Paper §7.2: WHISPER averages ~1.4 writes/epoch.
        for app in WhisperApp::ALL {
            let out = run_whisper(&Platform::default(), StrategyKind::NoSm, tiny(app));
            let wpe = out.writes_per_epoch();
            assert!(
                (0.8..2.5).contains(&wpe),
                "{app}: writes/epoch = {wpe}"
            );
        }
    }

    #[test]
    fn strategies_order_rc_worst() {
        let cfg = tiny(WhisperApp::Hashmap);
        let p = Platform::default();
        let base = run_whisper(&p, StrategyKind::NoSm, cfg).makespan as f64;
        let rc = run_whisper(&p, StrategyKind::SmRc, cfg).makespan as f64;
        let ob = run_whisper(&p, StrategyKind::SmOb, cfg).makespan as f64;
        let dd = run_whisper(&p, StrategyKind::SmDd, cfg).makespan as f64;
        assert!(rc > ob, "rc={rc} ob={ob}");
        assert!(rc > dd, "rc={rc} dd={dd}");
        assert!(rc / base > 2.0, "rc overhead {}", rc / base);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = tiny(WhisperApp::Ycsb);
        let a = run_whisper(&Platform::default(), StrategyKind::SmDd, cfg);
        let b = run_whisper(&Platform::default(), StrategyKind::SmDd, cfg);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.writes, b.writes);
    }
}
