//! Integration tests for the online adaptive mirroring control plane:
//! the disabled-default anchor (event-identity with legacy SM-AD), the
//! static-equivalence of phase-pure convergence, decision-replay
//! determinism, and the quorum-floor invariant under fault plans.

use pmsm::config::{AckPolicy, AdaptiveConfig, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::sched::RunOutcome;
use pmsm::coordinator::{Mirror, MirrorBuilder};
use pmsm::net::{FaultsConfig, FlushPolicy, OnLoss};
use pmsm::ptest::check;
use pmsm::runtime::{fallback_knob_predictor, fallback_predictor};
use pmsm::workloads::transact::{run_phased_on, Phase};

const SEED: u64 = 7;

fn mix() -> [Phase; 3] {
    [
        Phase { epochs: 1, writes: 64, txns: 12 },
        Phase { epochs: 4, writes: 1, txns: 40 },
        Phase { epochs: 64, writes: 2, txns: 8 },
    ]
}

/// SM-AD with the control plane attached (quorum floor = the configured
/// ack policy).
fn adaptive_mirror(repl: ReplicationConfig, cfg: AdaptiveConfig) -> Mirror {
    let plat = Platform::default();
    MirrorBuilder::new(plat.clone(), StrategyKind::SmAd)
        .replication(repl)
        .predictor(fallback_predictor(&plat))
        .knob_predictor(fallback_knob_predictor(&plat))
        .adaptive(cfg)
        .build()
        .expect("valid adaptive mirror")
}

fn assert_same_events(a: &RunOutcome, b: &RunOutcome, what: &str) {
    assert_eq!(a.makespan, b.makespan, "{what}: makespan");
    assert_eq!(a.busy_ns, b.busy_ns, "{what}: busy_ns");
    assert_eq!(a.txns, b.txns, "{what}: txns");
    assert_eq!(a.writes, b.writes, "{what}: writes");
    assert_eq!(a.epochs, b.epochs, "{what}: epochs");
    assert_eq!(a.doorbells, b.doorbells, "{what}: doorbells");
    assert_eq!(a.posted_wqes, b.posted_wqes, "{what}: posted_wqes");
    assert_eq!(a.wire_wqes, b.wire_wqes, "{what}: wire_wqes");
    assert_eq!(a.fences_issued, b.fences_issued, "{what}: fences_issued");
}

/// The anchor: `[adaptive]` disabled (the default) keeps SM-AD on the
/// legacy binary-chooser path, event for event — attaching a disabled
/// config must not perturb a single timestamp or counter.
#[test]
fn disabled_adaptive_is_event_identical_to_legacy_sm_ad() {
    let plat = Platform::default();
    let repl = ReplicationConfig::new(2, AckPolicy::All);
    let mut legacy = MirrorBuilder::new(plat.clone(), StrategyKind::SmAd)
        .replication(repl)
        .predictor(fallback_predictor(&plat))
        .build()
        .expect("legacy sm-ad");
    let mut anchored = adaptive_mirror(repl, AdaptiveConfig::default());
    assert!(!anchored.adaptive().enabled, "default config is disabled");

    let a = run_phased_on(&mut legacy, &mix(), 2, SEED);
    let b = run_phased_on(&mut anchored, &mix(), 2, SEED);
    assert_same_events(&a, &b, "disabled anchor");
    // Same mode routing, and the disabled plane applies no knob vector.
    assert_eq!(a.decisions.chose_ob, b.decisions.chose_ob);
    assert_eq!(a.decisions.chose_dd, b.decisions.chose_dd);
    assert_eq!(b.decisions.adaptive_switches, 0);
    assert!(b.decisions.quorum_hist.is_empty());
    assert!(b.decisions.cap_hist.is_empty());
    assert_eq!(b.decisions.feedback_samples, 0);
}

/// Phase-pure convergence is exact: with feedback off (pure model
/// drive), the controller pins each class's knob vector from txn 1, so
/// the run is event-identical to the static strategy configured with
/// that same vector.
#[test]
fn phase_pure_adaptive_matches_its_static_equivalent() {
    let plat = Platform::default();
    let repl = ReplicationConfig::new(2, AckPolicy::Quorum(1));
    let model_only = AdaptiveConfig {
        feedback: false,
        ..AdaptiveConfig::enabled()
    };
    // (class, static mode, static cap) — the model's per-class optima
    // at backups=2 (pinned by the unit tests in replication::adaptive).
    for (phase, kind, cap) in [
        (Phase { epochs: 4, writes: 1, txns: 30 }, StrategyKind::SmDd, 1usize),
        (Phase { epochs: 1, writes: 64, txns: 15 }, StrategyKind::SmOb, 32),
    ] {
        let mut adaptive = adaptive_mirror(repl, model_only);
        let got = run_phased_on(&mut adaptive, &[phase], 1, SEED);

        let mut fixed = MirrorBuilder::new(plat.clone(), kind)
            .replication(repl)
            .batching(FlushPolicy::Cap(cap))
            .build()
            .expect("static equivalent");
        let want = run_phased_on(&mut fixed, &[phase], 1, SEED);

        let what = format!("{}x{} vs {kind}/cap{cap}", phase.epochs, phase.writes);
        assert_same_events(&got, &want, &what);
        assert_eq!(got.decisions.adaptive_switches, 0, "{what}: no re-tuning");
        assert_eq!(got.decisions.cap_hist, vec![(cap, phase.txns)], "{what}");
    }
}

/// Decision replay: the controller is a pure function of the (seeded)
/// event stream — two identical runs produce identical outcomes AND
/// identical decision statistics, including the feedback accumulators.
#[test]
fn decision_replay_is_deterministic() {
    let repl = ReplicationConfig::new(2, AckPolicy::Quorum(1));
    let run = || {
        let mut m = adaptive_mirror(repl, AdaptiveConfig::enabled());
        run_phased_on(&mut m, &mix(), 2, SEED)
    };
    let a = run();
    let b = run();
    assert_same_events(&a, &b, "replay");
    assert_eq!(a.decisions.chose_ob, b.decisions.chose_ob);
    assert_eq!(a.decisions.chose_dd, b.decisions.chose_dd);
    assert_eq!(a.decisions.adaptive_switches, b.decisions.adaptive_switches);
    assert_eq!(a.decisions.quorum_hist, b.decisions.quorum_hist);
    assert_eq!(a.decisions.cap_hist, b.decisions.cap_hist);
    assert_eq!(a.decisions.feedback_samples, b.decisions.feedback_samples);
    assert!(
        a.decisions.err_pct_sum.to_bits() == b.decisions.err_pct_sum.to_bits(),
        "feedback error accumulator must replay bit-identically"
    );
    assert!(a.decisions.feedback_samples > 0, "feedback must engage");
}

/// The durability floor is inviolable: under randomized backup kill /
/// rejoin plans (degrade mode, so every run completes), the controller
/// never picks an ack quorum below the configured policy requirement.
#[test]
fn prop_quorum_never_undercuts_floor_under_faults() {
    let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
    let floor = repl.required();
    // Fault-free span bounds the kill placement.
    let span = {
        let mut m = adaptive_mirror(repl, AdaptiveConfig::enabled());
        run_phased_on(&mut m, &mix(), 1, SEED).makespan
    };
    check("adaptive-quorum-floor", 12, |g| {
        let victim = g.usize(0, 2);
        let kill_at = g.u64(span / 10, span);
        let plan = if g.bool() {
            format!("kill:{victim}@{kill_at},rejoin:{victim}@{}", kill_at + span / 4)
        } else {
            format!("kill:{victim}@{kill_at}")
        };
        let plat = Platform::default();
        let mut m = MirrorBuilder::new(plat.clone(), StrategyKind::SmAd)
            .replication(repl)
            .predictor(fallback_predictor(&plat))
            .knob_predictor(fallback_knob_predictor(&plat))
            .adaptive(AdaptiveConfig::enabled())
            .faults(FaultsConfig::with_plan(&plan, OnLoss::Degrade).unwrap())
            .build()
            .expect("adaptive + faults");
        let out = run_phased_on(&mut m, &mix(), 1, g.u64(1, 1 << 30));
        assert!(out.stalled.is_none(), "degrade must complete ({plan})");
        assert_eq!(out.txns, mix().iter().map(|p| p.txns).sum::<u64>());
        let d = &out.decisions;
        assert_eq!(
            d.chose_ob + d.chose_dd,
            out.txns,
            "one decision per txn ({plan})"
        );
        for (k, n) in d.quorum_hist.iter().enumerate() {
            assert!(
                k >= floor || *n == 0,
                "decision below the floor: k={k} n={n} ({plan})"
            );
        }
    });
}
