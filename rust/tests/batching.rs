//! Batching-equivalence suite for the staged WQE pipeline
//! (`net::wqe` / `Fabric::post_data`): property tests asserting that
//! doorbell batching changes *when* doorbells ring but never *what*
//! replicates — every backup's durability ledger carries the same
//! events in the same per-backup order as the eager path — plus the
//! fault-interaction units (a kill between stage and doorbell drops
//! only the dead backup's staged WQEs; a rejoin leaves no ghost
//! entries).

use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{Mirror, ThreadCtx};
use pmsm::net::{Fabric, FaultsConfig, FlushPolicy, OnLoss, WriteMeta};
use pmsm::ptest::{check, Gen};
use pmsm::recovery;
use pmsm::sim::ThreadClock;

fn meta(addr: u64, epoch: u32, seq: u64) -> WriteMeta {
    WriteMeta {
        addr,
        val: seq,
        thread: 0,
        txn: 0,
        epoch,
        seq,
    }
}

/// Per-backup ledger projected to its replication-relevant coordinates
/// (everything but the durability instant, which batching may move), in
/// ledger (persist-record) order.
fn ledger_events(m: &Mirror, backup: usize) -> Vec<(u32, u64, u64, u64, u32)> {
    m.backup(backup)
        .ledger
        .events()
        .iter()
        .map(|e| (e.thread, e.seq, e.addr, e.val, e.epoch))
        .collect()
}

/// Drive a random single-thread Transact-shaped workload and return the
/// per-backup ledgers plus the run's doorbell/WQE counters.
fn drive(
    kind: StrategyKind,
    backups: usize,
    policy: FlushPolicy,
    shape: &[(u32, u32)], // (epochs, writes) per transaction
) -> Mirror {
    let mut m = Mirror::with_replication(
        Platform::default(),
        kind,
        ReplicationConfig::new(backups, AckPolicy::All),
        true,
    )
    .unwrap();
    m.set_batching(policy);
    let mut t = ThreadCtx::new(0);
    for (i, &(epochs, writes)) in shape.iter().enumerate() {
        m.txn_begin(&mut t, None);
        for e in 0..epochs {
            for w in 0..writes {
                let addr = 0x1000_0000 + ((i as u64 * 7 + e as u64 * 3 + w as u64) % 32) * 64;
                m.store(&mut t, addr, i as u64);
                m.clwb(&mut t, addr);
            }
            m.sfence(&mut t);
        }
        m.txn_commit(&mut t);
    }
    m
}

/// The tentpole's equivalence property: for random workloads, any batch
/// cap in {1, 4, 16} and the fence policy, under all three SM
/// strategies and 1..3 backups, every backup's durability ledger is
/// identical to the eager path's (same events, same per-backup order —
/// thread/seq/addr/val/epoch; only instants move) and per-thread epoch
/// ordering still holds on the batched ledgers.
#[test]
fn prop_batched_ledgers_match_eager() {
    check("batching-ledger-equivalence", 25, |g: &mut Gen| {
        let kind = *g.pick(&[StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd]);
        let backups = g.usize(1, 3);
        let txns = g.u64(1, 4);
        let shape: Vec<(u32, u32)> = (0..txns)
            .map(|_| (g.u64(1, 5) as u32, g.u64(1, 8) as u32))
            .collect();
        let eager = drive(kind, backups, FlushPolicy::Eager, &shape);
        for policy in [
            FlushPolicy::Cap(1),
            FlushPolicy::Cap(4),
            FlushPolicy::Cap(16),
            FlushPolicy::Fence,
        ] {
            let batched = drive(kind, backups, policy, &shape);
            for b in 0..backups {
                assert_eq!(
                    ledger_events(&eager, b),
                    ledger_events(&batched, b),
                    "{kind:?} backup {b} under {policy}: ledger diverged"
                );
                recovery::check_epoch_ordering(&batched.backup(b).ledger)
                    .unwrap_or_else(|e| panic!("{kind:?} {policy}: {e}"));
            }
            assert_eq!(batched.posted_wqes(), eager.posted_wqes(), "{kind:?} {policy}");
            assert!(
                batched.doorbells() <= eager.doorbells(),
                "{kind:?} {policy}: batching rang more doorbells"
            );
            if policy == FlushPolicy::Cap(1) {
                // The anchor: cap 1 IS eager — same doorbell count too.
                assert_eq!(batched.doorbells(), eager.doorbells(), "{kind:?}");
            }
        }
    });
}

/// Batching must never change commit accounting or recovery-relevant
/// durability: the fence-flushed run commits every transaction and its
/// durability fence still covers every replicated write.
#[test]
fn prop_batched_dfence_covers_everything() {
    check("batching-dfence-coverage", 20, |g: &mut Gen| {
        let kind = *g.pick(&[StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd]);
        let epochs = g.u64(1, 6) as u32;
        let writes = g.u64(1, 8) as u32;
        let cap = *g.pick(&[4usize, 16]);
        let mut m = Mirror::with_replication(
            Platform::default(),
            kind,
            ReplicationConfig::new(2, AckPolicy::All),
            true,
        )
        .unwrap();
        m.set_batching(FlushPolicy::Cap(cap));
        let mut t = ThreadCtx::new(0);
        m.txn_begin(&mut t, None);
        for e in 0..epochs {
            for w in 0..writes {
                let addr = 0x2000_0000 + (e * writes + w) as u64 * 64;
                m.store(&mut t, addr, 7);
                m.clwb(&mut t, addr);
            }
            m.sfence(&mut t);
        }
        m.txn_commit(&mut t);
        assert_eq!(t.txns_done, 1);
        for b in 0..2 {
            let ledger = &m.backup(b).ledger;
            assert_eq!(ledger.len() as u64, (epochs * writes) as u64, "backup {b}");
            for ev in ledger.events() {
                assert!(
                    ev.at <= t.last_dfence,
                    "backup {b}: write at {} after dfence {}",
                    ev.at,
                    t.last_dfence
                );
            }
        }
    });
}

/// A backup killed between stage and doorbell receives nothing from the
/// staged batch (the WQEs are dropped, not parked), while survivors get
/// the full chain.
#[test]
fn kill_between_stage_and_doorbell_drops_only_dead_wqes() {
    let p = Platform::default();
    let faults = FaultsConfig::with_plan("kill:1@2000", OnLoss::Halt).unwrap();
    let mut f = Fabric::with_faults(
        &p,
        &ReplicationConfig::new(3, AckPolicy::Quorum(2)),
        faults,
        true,
    )
    .with_batching(FlushPolicy::Fence);
    let mut t = ThreadClock::new(0);
    for s in 0..5u64 {
        f.post_write_wt(&mut t, meta(0x40 * (1 + s), 0, s));
    }
    assert!(t.now < 2_000, "staging must predate the kill");
    t.wait_until(3_000);
    f.rdfence(&mut t);
    assert!(f.stall().is_none());
    for b in [0usize, 2] {
        assert_eq!(f.backup(b).ledger.len(), 5, "survivor {b}");
    }
    assert_eq!(f.backup(1).ledger.len(), 0, "dead backup got a staged WQE");
    assert_eq!(f.staged_pending(), 0, "dropped WQEs must not linger");
}

/// After a kill between stage and doorbell, a rejoin must produce no
/// ghost ledger entries: everything the dead backup missed arrives only
/// through the catch-up resync (durability stamped at or after the
/// resync completes — never backdated into the dead window), and the
/// rejoined ledger converges to the survivors' event set.
#[test]
fn rejoin_after_dropped_batch_has_no_ghost_entries() {
    let p = Platform::default();
    let kill_at = 2_000u64;
    let rejoin_at = 50_000u64;
    let faults = FaultsConfig::with_plan(
        &format!("kill:1@{kill_at},rejoin:1@{rejoin_at}"),
        OnLoss::Halt,
    )
    .unwrap();
    let mut f = Fabric::with_faults(
        &p,
        &ReplicationConfig::new(3, AckPolicy::Quorum(2)),
        faults,
        true,
    )
    .with_batching(FlushPolicy::Fence);
    let mut t = ThreadClock::new(0);
    // Epoch 0 staged before the kill, doorbell rung after it: backup 1's
    // copies are dropped.
    for s in 0..4u64 {
        f.post_write_wt(&mut t, meta(0x40 * (1 + s), 0, s));
    }
    assert!(t.now < kill_at);
    t.wait_until(3_000);
    f.rdfence(&mut t);
    assert_eq!(f.backup(1).ledger.len(), 0);
    // Past the rejoin + resync window: epoch 1 reaches everyone again.
    t.wait_until(rejoin_at + 100_000);
    for s in 4..6u64 {
        f.post_write_wt(&mut t, meta(0x40 * (1 + s), 1, s));
    }
    f.rdfence(&mut t);
    assert!(f.stall().is_none());
    assert_eq!(f.alive_count(), 3, "backup 1 must be back in the quorum");
    // Converged: the rejoined backup holds exactly the survivors' events.
    let proj = |b: usize| -> Vec<(u32, u64)> {
        let mut evs: Vec<(u32, u64)> = f
            .backup(b)
            .ledger
            .events()
            .iter()
            .map(|e| (e.thread, e.seq))
            .collect();
        evs.sort_unstable();
        evs
    };
    assert_eq!(proj(1), proj(0), "rejoined backup must converge");
    // No ghosts: nothing on backup 1 claims durability inside its dead
    // window — dropped WQEs arrive only via the resync, at/after rejoin.
    for ev in f.backup(1).ledger.events() {
        assert!(
            ev.at < kill_at || ev.at >= rejoin_at,
            "ghost entry: seq {} stamped {} inside the dead window",
            ev.seq,
            ev.at
        );
    }
    recovery::check_epoch_ordering(&f.backup(1).ledger).unwrap();
}

/// End-to-end anchor at the coordinator level: an eager run and a
/// `batch_cap = 1` run are event-for-event identical (same thread
/// timeline, same ledgers, same doorbell count).
#[test]
fn cap_one_run_is_event_identical_to_eager() {
    let run = |policy: FlushPolicy| -> (u64, Vec<(u32, u64, u64, u64, u32)>, u64) {
        let mut m = Mirror::with_replication(
            Platform::default(),
            StrategyKind::SmOb,
            ReplicationConfig::new(2, AckPolicy::All),
            true,
        )
        .unwrap();
        m.set_batching(policy);
        let mut t = ThreadCtx::new(0);
        for i in 0..5u64 {
            m.txn_begin(&mut t, None);
            for e in 0..3u32 {
                let addr = 0x3000_0000 + (i * 3 + e as u64) * 64;
                m.store(&mut t, addr, i);
                m.clwb(&mut t, addr);
                m.sfence(&mut t);
            }
            m.txn_commit(&mut t);
        }
        (t.now(), ledger_events(&m, 0), m.doorbells())
    };
    let (eager_now, eager_ledger, eager_doorbells) = run(FlushPolicy::Eager);
    let (cap1_now, cap1_ledger, cap1_doorbells) = run(FlushPolicy::Cap(1));
    assert_eq!(eager_now, cap1_now, "cap:1 must be the eager anchor");
    assert_eq!(eager_ledger, cap1_ledger);
    assert_eq!(eager_doorbells, cap1_doorbells);
}
