//! Coalescing-equivalence suite for the flush-time coalescer
//! (`net::wqe::coalesce_chain` / `Fabric::flush`): property tests
//! asserting that scatter-gather merging changes *how* lines travel but
//! never *what* persists (ledger event-identity vs `none`), that write
//! combining preserves durable fence-point state, last-writer ledger
//! entries and recovery verdicts while eliding only superseded
//! same-epoch overwrites, and that `--coalesce none` is the bit-exact
//! anchor of the PR-4 batching pipeline — plus the fault-interaction
//! unit (a kill between stage and doorbell drops the whole chain, so a
//! span never partially applies).

use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{Mirror, ShardMapSpec, ShardingConfig, ThreadCtx};
use pmsm::net::{CoalesceMode, Fabric, FaultsConfig, FlushPolicy, OnLoss, WriteMeta};
use pmsm::ptest::{check, Gen};
use pmsm::recovery::{self, TxnHistory};
use pmsm::sim::ThreadClock;
use pmsm::txn::Txn;
use pmsm::{Addr, Ns, LINE};
use std::collections::HashMap;

const MODES: [CoalesceMode; 4] = [
    CoalesceMode::None,
    CoalesceMode::Combine,
    CoalesceMode::Sg,
    CoalesceMode::Full,
];

/// One epoch of the randomized locality workload: `rewrites` hot-header
/// writes, then `appends` contiguous lines, then `scatter` strided
/// lines.
#[derive(Clone, Copy, Debug)]
struct Epoch {
    rewrites: u32,
    appends: u32,
    scatter: u32,
}

/// Per-backup ledger projected to its replication-relevant coordinates
/// (everything but the durability instant, which coalescing may move),
/// in ledger (persist-record) order.
fn ledger_events(m: &Mirror, backup: usize) -> Vec<(u32, u64, u64, u64, u32)> {
    m.backup(backup)
        .ledger
        .events()
        .iter()
        .map(|e| (e.thread, e.seq, e.addr, e.val, e.epoch))
        .collect()
}

/// Drive a deterministic locality-heavy workload (shape fixed by the
/// caller, identical across modes) and return the mirror.
fn drive(
    kind: StrategyKind,
    backups: usize,
    policy: FlushPolicy,
    mode: CoalesceMode,
    txns: &[Vec<Epoch>],
) -> Mirror {
    let mut m = Mirror::with_replication(
        Platform::default(),
        kind,
        ReplicationConfig::new(backups, AckPolicy::All),
        true,
    )
    .unwrap();
    m.set_batching(policy);
    m.set_coalescing(mode);
    let hot: Addr = 0x5000_0000;
    let mut cursor: Addr = 0x5001_0000;
    let mut t = ThreadCtx::new(0);
    for (i, epochs) in txns.iter().enumerate() {
        m.txn_begin(&mut t, None);
        for e in epochs {
            for r in 0..e.rewrites {
                m.store(&mut t, hot, i as u64 * 100 + r as u64);
                m.clwb(&mut t, hot);
            }
            for _ in 0..e.appends {
                m.store(&mut t, cursor, i as u64);
                m.clwb(&mut t, cursor);
                cursor += LINE;
            }
            for s in 0..e.scatter {
                // Stride-3 lines: never contiguous, never repeated.
                let addr = 0x7000_0000 + (i as Addr * 16 + s as Addr) * 3 * LINE;
                m.store(&mut t, addr, s as u64);
                m.clwb(&mut t, addr);
            }
            m.sfence(&mut t);
        }
        m.txn_commit(&mut t);
    }
    m
}

fn random_shape(g: &mut Gen) -> Vec<Vec<Epoch>> {
    let txns = g.u64(1, 4);
    (0..txns)
        .map(|_| {
            let epochs = g.u64(1, 4);
            (0..epochs)
                .map(|_| Epoch {
                    rewrites: g.u64(0, 3) as u32,
                    appends: g.u64(0, 5) as u32,
                    scatter: g.u64(0, 2) as u32,
                })
                .collect()
        })
        .collect()
}

/// Scatter-gather is transport-only: for random workloads under all
/// three SM strategies, 1..3 backups and both staged policies, the
/// per-backup ledgers are event-identical to the uncoalesced run —
/// same events, same order, same coordinates; only instants (not
/// checked here) and the wire-WQE count may change.
#[test]
fn prop_sg_ledgers_identical_to_none() {
    check("coalescing-sg-identity", 25, |g: &mut Gen| {
        let kind = *g.pick(&[StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd]);
        let backups = g.usize(1, 3);
        let policy = *g.pick(&[FlushPolicy::Fence, FlushPolicy::Cap(4)]);
        let shape = random_shape(g);
        let none = drive(kind, backups, policy, CoalesceMode::None, &shape);
        let sg = drive(kind, backups, policy, CoalesceMode::Sg, &shape);
        for b in 0..backups {
            assert_eq!(
                ledger_events(&none, b),
                ledger_events(&sg, b),
                "{kind:?} backup {b}: sg changed ledger events"
            );
            recovery::check_epoch_ordering(&sg.backup(b).ledger)
                .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        }
        assert_eq!(sg.posted_wqes(), none.posted_wqes(), "sg drops nothing");
        assert!(sg.wire_wqes() <= none.wire_wqes());
        assert_eq!(sg.combined_writes(), 0);
        assert!(sg.doorbells() <= sg.wire_wqes());
    });
}

/// Write combining preserves everything recovery can see: the combined
/// ledger is an ordered subsequence of the uncoalesced one, the final
/// durable image per backup is identical, each line's last (highest
/// seq) entry survives verbatim, per-thread epoch ordering holds, and
/// the elided count exactly accounts for the posted-line delta.
#[test]
fn prop_combine_is_last_writer_subsequence() {
    check("coalescing-combine-soundness", 25, |g: &mut Gen| {
        let kind = *g.pick(&[StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd]);
        let backups = g.usize(1, 3);
        let policy = *g.pick(&[FlushPolicy::Fence, FlushPolicy::Cap(4)]);
        let mode = *g.pick(&[CoalesceMode::Combine, CoalesceMode::Full]);
        let shape = random_shape(g);
        let none = drive(kind, backups, policy, CoalesceMode::None, &shape);
        let comb = drive(kind, backups, policy, mode, &shape);
        for b in 0..backups {
            let eager = ledger_events(&none, b);
            let batched = ledger_events(&comb, b);
            if kind == StrategyKind::SmRc {
                // SM-RC's remote already coalesces pending same-line
                // writes (keeping the FIRST insert's drain slot), so
                // combining may legally permute the rcommit drain's
                // record order within an epoch: assert set inclusion.
                for ev in &batched {
                    assert!(
                        eager.contains(ev),
                        "{kind:?} {mode} backup {b}: event {ev:?} absent \
                         from the uncoalesced ledger"
                    );
                }
            } else {
                // Write-through strategies record in arrival order:
                // batched is eager with some events elided, nothing
                // reordered or invented (ordered subsequence).
                let mut it = eager.iter();
                for ev in &batched {
                    assert!(
                        it.any(|e| e == ev),
                        "{kind:?} {mode} backup {b}: event {ev:?} missing or \
                         out of order vs the uncoalesced ledger"
                    );
                }
            }
            // Identical final durable image.
            assert_eq!(
                none.backup(b).ledger.image_at(Ns::MAX),
                comb.backup(b).ledger.image_at(Ns::MAX),
                "{kind:?} {mode} backup {b}: durable image diverged"
            );
            // The last writer of every line survives verbatim.
            let last = |evs: &[(u32, u64, u64, u64, u32)]| -> HashMap<u64, (u32, u64, u64)> {
                let mut m = HashMap::new();
                for &(th, seq, addr, val, _) in evs {
                    m.insert(addr, (th, seq, val));
                }
                m
            };
            assert_eq!(last(&eager), last(&batched), "{kind:?} backup {b}");
            recovery::check_epoch_ordering(&comb.backup(b).ledger)
                .unwrap_or_else(|e| panic!("{kind:?} {mode}: {e}"));
        }
        // Elided lines account exactly for the wire delta.
        assert_eq!(
            none.posted_wqes() - comb.posted_wqes(),
            comb.combined_writes(),
            "{kind:?} {mode}: combined_writes must equal the posted delta"
        );
        assert!(comb.wire_wqes() <= comb.posted_wqes());
    });
}

/// Run the undo-log transaction runtime and return (mirror, history).
fn run_txn_workload(
    kind: StrategyKind,
    backups: usize,
    mode: CoalesceMode,
    faults: FaultsConfig,
    sharding: ShardingConfig,
    writes: &[Vec<(Addr, u64)>],
) -> (Mirror, TxnHistory) {
    let repl = ReplicationConfig::new(
        backups,
        if backups >= 3 { AckPolicy::Quorum(2) } else { AckPolicy::All },
    );
    let mut m = Mirror::try_build_sharded(
        Platform::default(),
        kind,
        None,
        repl,
        faults,
        sharding,
        true,
    )
    .unwrap();
    m.set_batching(FlushPolicy::Fence);
    m.set_coalescing(mode);
    let log = pmsm::pstore::log_base_for(0);
    let mut t = ThreadCtx::new(0);
    let mut hist = TxnHistory::new(Default::default());
    let mut image: HashMap<Addr, u64> = HashMap::new();
    for txn in writes {
        let mut tx = Txn::begin(&mut m, &mut t, log, None);
        for &(addr, val) in txn {
            tx.write(&mut m, &mut t, addr, val);
            image.insert(addr, val);
        }
        tx.commit(&mut m, &mut t);
        if m.stall().is_some() {
            break;
        }
        hist.commit(image.clone(), t.last_dfence);
    }
    m.settle(t.now());
    (m, hist)
}

/// The recovery-verdict property: for random undo-log workloads under
/// all three SM strategies and 1..3 backups, the full crash-point sweep
/// (`check_group_crashes` — Guarantee-1 + group Guarantee-2) passes
/// under every coalesce mode, commits the same transactions, and
/// reaches the same durable data state.
#[test]
fn prop_recovery_verdicts_hold_across_modes() {
    check("coalescing-recovery-verdicts", 12, |g: &mut Gen| {
        let kind = *g.pick(&[StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd]);
        let backups = g.usize(1, 3);
        let d0: Addr = 0x20_0000;
        let data = [d0, d0 + 64, d0 + 128];
        let txns = g.u64(2, 4);
        let writes: Vec<Vec<(Addr, u64)>> = (0..txns)
            .map(|i| {
                let n = g.u64(1, 3);
                (0..n)
                    .map(|j| (*g.pick(&data), i * 10 + j))
                    .collect()
            })
            .collect();
        let log = pmsm::pstore::log_base_for(0);
        let required = if backups >= 3 { 2 } else { backups };
        let mut committed = None;
        for mode in MODES {
            let (m, hist) = run_txn_workload(
                kind,
                backups,
                mode,
                FaultsConfig::default(),
                ShardingConfig::default(),
                &writes,
            );
            assert!(m.stall().is_none());
            // Same committed prefix in every mode.
            let c = committed.get_or_insert(hist.committed());
            assert_eq!(*c, hist.committed(), "{kind:?} {mode}");
            assert_eq!(hist.committed() as u64, txns, "{kind:?} {mode}");
            recovery::check_group_epoch_ordering(&m.fabric().ledgers())
                .unwrap_or_else(|e| panic!("{kind:?} {mode}: {e}"));
            recovery::check_group_crashes(
                &m.fabric().ledgers(),
                &hist,
                &[log],
                &data,
                required,
            )
            .unwrap_or_else(|e| panic!("{kind:?} {mode} backups={backups}: {e}"));
        }
    });
}

/// Fault-aware + sharded variants of the verdict property: a mid-run
/// backup kill (tolerated by quorum:2/degrade) and a 2-shard range
/// split both keep `check_faulted_group_crashes` /
/// `check_sharded_group_crashes` green under every coalesce mode.
#[test]
fn prop_recovery_verdicts_hold_faulted_and_sharded() {
    check("coalescing-faulted-sharded-verdicts", 8, |g: &mut Gen| {
        let kind = *g.pick(&[StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd]);
        let d0: Addr = 0x20_0000;
        let data = [d0, d0 + 64];
        let txns = g.u64(2, 4);
        let writes: Vec<Vec<(Addr, u64)>> = (0..txns)
            .map(|i| vec![(d0, 100 + i), (d0 + 64, 200 + i)])
            .collect();
        let log = pmsm::pstore::log_base_for(0);
        let kill_at = g.u64(1_000, 80_000);
        for mode in MODES {
            // Faulted: 3 backups, quorum:2, one kill mid-run, degrade.
            let faults = FaultsConfig::with_plan(
                &format!("kill:2@{kill_at}"),
                OnLoss::Degrade,
            )
            .unwrap();
            let (m, hist) = run_txn_workload(
                kind,
                3,
                mode,
                faults,
                ShardingConfig::default(),
                &writes,
            );
            assert!(m.stall().is_none(), "{kind:?} {mode}: quorum:2 tolerates it");
            assert_eq!(hist.committed() as u64, txns);
            recovery::check_faulted_group_crashes(
                &m.fabric().ledgers(),
                &hist,
                &[log],
                &data,
                2,
                OnLoss::Degrade,
                &m.fabric().timeline(),
            )
            .unwrap_or_else(|e| panic!("{kind:?} {mode} faulted: {e}"));
            // Sharded: adjacent data lines split across 2 range shards.
            let sharding = ShardingConfig::new(2, ShardMapSpec::Range { stripe_lines: 1 });
            let (m, hist) = run_txn_workload(
                kind,
                2,
                mode,
                FaultsConfig::default(),
                sharding,
                &writes,
            );
            assert!(m.stall().is_none());
            recovery::check_sharded_group_crashes(
                &m.shard_ledgers(),
                &m.timelines(),
                &hist,
                &[log],
                &data,
                2,
                OnLoss::Halt,
                m.shard_map(),
            )
            .unwrap_or_else(|e| panic!("{kind:?} {mode} sharded: {e}"));
        }
    });
}

/// The anchor, end-to-end: a fence-batched run with `--coalesce none`
/// is event-for-event identical to one that never touched the
/// coalescing API — same thread timeline, ledgers and counters. And a
/// workload with no adjacency and no rewrites is a fixpoint of every
/// mode: even `full` reproduces the anchor timeline bit-exactly.
#[test]
fn coalesce_none_and_fixpoint_workloads_are_bit_exact() {
    let shape = vec![vec![
        Epoch { rewrites: 0, appends: 0, scatter: 4 },
        Epoch { rewrites: 0, appends: 0, scatter: 3 },
    ]];
    let run = |mode: Option<CoalesceMode>| -> (Ns, Vec<(u32, u64, u64, u64, u32)>, u64, u64) {
        let mut m = Mirror::with_replication(
            Platform::default(),
            StrategyKind::SmOb,
            ReplicationConfig::new(2, AckPolicy::All),
            true,
        )
        .unwrap();
        m.set_batching(FlushPolicy::Fence);
        if let Some(mode) = mode {
            m.set_coalescing(mode);
        }
        let hot: Addr = 0x5000_0000;
        let mut cursor: Addr = 0x5001_0000;
        let mut t = ThreadCtx::new(0);
        for epochs in &shape {
            m.txn_begin(&mut t, None);
            for e in epochs {
                for r in 0..e.rewrites {
                    m.store(&mut t, hot, r as u64);
                    m.clwb(&mut t, hot);
                }
                for _ in 0..e.appends {
                    m.store(&mut t, cursor, 1);
                    m.clwb(&mut t, cursor);
                    cursor += LINE;
                }
                for s in 0..e.scatter {
                    let addr = 0x7000_0000 + s as Addr * 3 * LINE;
                    m.store(&mut t, addr, s as u64);
                    m.clwb(&mut t, addr);
                }
                m.sfence(&mut t);
            }
            m.txn_commit(&mut t);
        }
        (t.now(), ledger_events(&m, 0), m.wire_wqes(), m.doorbells())
    };
    let plain = run(None);
    let none = run(Some(CoalesceMode::None));
    assert_eq!(plain, none, "None must be the untouched batching pipeline");
    for mode in [CoalesceMode::Combine, CoalesceMode::Sg, CoalesceMode::Full] {
        let out = run(Some(mode));
        assert_eq!(
            plain, out,
            "{mode}: a rewrite-free, adjacency-free workload must be a \
             fixpoint — bit-exact timeline included"
        );
    }
}

/// A backup killed between stage and doorbell loses its whole chain
/// before coalescing runs: survivors receive their full coalesced
/// chains (spans intact), the corpse's ledger shows nothing — a span
/// never partially applies across a kill.
#[test]
fn kill_between_stage_and_doorbell_drops_whole_coalesced_chain() {
    let p = Platform::default();
    let faults = FaultsConfig::with_plan("kill:1@2000", OnLoss::Halt).unwrap();
    let mut f = Fabric::with_faults(
        &p,
        &ReplicationConfig::new(3, AckPolicy::Quorum(2)),
        faults,
        true,
    )
    .with_batching(FlushPolicy::Fence)
    .with_coalescing(CoalesceMode::Full);
    let mut t = ThreadClock::new(0);
    // A hot rewrite + a contiguous run, staged before the kill instant.
    for s in 0..2u64 {
        f.post_write_wt(
            &mut t,
            WriteMeta { addr: 0x40, val: s, thread: 0, txn: 0, epoch: 0, seq: s },
        );
    }
    for s in 0..4u64 {
        f.post_write_wt(
            &mut t,
            WriteMeta {
                addr: 0x1000 + 0x40 * s,
                val: s,
                thread: 0,
                txn: 0,
                epoch: 0,
                seq: 2 + s,
            },
        );
    }
    assert!(t.now < 2_000, "staging must predate the kill, t={}", t.now);
    t.wait_until(3_000);
    f.rdfence(&mut t);
    assert!(f.stall().is_none(), "quorum:2 tolerates the loss");
    for b in [0usize, 2] {
        // 1 surviving hot line + 4 appends per survivor.
        assert_eq!(f.backup(b).ledger.len(), 5, "survivor {b}");
    }
    assert_eq!(f.backup(1).ledger.len(), 0, "dead backup saw a staged WQE");
    assert_eq!(f.staged_pending(), 0, "dropped WQEs must not linger");
    // Survivors' chains coalesced: 2 wire WQEs each (hot + 4-line span)
    // and one elided hot overwrite each.
    assert_eq!(f.wire_wqes_total(), 4);
    assert_eq!(f.combined_writes, 2);
    assert_eq!(f.span_hist().max(), 4);
}
