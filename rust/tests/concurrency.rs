//! Integration: the concurrent primary — commit pipelines, cross-thread
//! group fencing, and detectable pstore operations.
//!
//! Three layers of guarantees are pinned here:
//!
//! 1. **The serial anchor.** The default concurrency shape
//!    (`--commit-pipelines 1 --group-fence-ns 0`) must be *event-for-event*
//!    identical to the pre-concurrency replica-group path: every
//!    `RunOutcome` counter and every backup ledger event, across
//!    strategies and thread counts.
//! 2. **Group-fence soundness.** A piggybacked fence skips the requester's
//!    verb post, but the responder-side drain still persists everything —
//!    so backup ledgers, persist horizons, and per-txn durability acks
//!    are unchanged; only issued-fence count and primary busy time drop.
//! 3. **Detectable-op crash recovery.** For every possible crash instant
//!    in a run of detectable operations (every durable-event time in the
//!    backup ledger), recovering the image — rollback, checkpoint read,
//!    and (when needed) deterministic replay — must land on exactly the
//!    durable image and replicated write sequence of the uninterrupted
//!    run. Exercised for all three stamped structures (crit-bit tree,
//!    hashmap, echo KV batches).

use std::collections::HashMap;

use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{ConcurrencyConfig, Mirror, ThreadCtx};
use pmsm::mem::{DurEvent, DurabilityLog};
use pmsm::pstore::detect::{
    kv_apply_batch, map_put, read_checkpoint, rollback_in_image, tree_insert, Checkpoint,
    OP_KV_BATCH, OP_MAP_PUT, OP_TREE_INSERT,
};
use pmsm::pstore::{
    log_base_for, CritBitTree, DetectCtx, KvStore, PHashMap, PmHeap, REGION_CKPT, REGION_HEAP,
    REGION_LOGS, REGION_ROOTS,
};
use pmsm::workloads::transact::{run_transact_concurrent, run_transact_on, run_transact_with};
use pmsm::workloads::TransactConfig;
use pmsm::{Addr, Ns};

fn repl2() -> ReplicationConfig {
    ReplicationConfig::new(2, AckPolicy::All)
}

fn cfg(threads: usize, txns: u64) -> TransactConfig {
    TransactConfig {
        epochs: 4,
        writes: 1,
        txns,
        threads,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// 1. The serial anchor: default concurrency shape == the legacy path.
// ---------------------------------------------------------------------------

#[test]
fn default_concurrency_pins_the_serial_path_event_for_event() {
    let plat = Platform::default();
    for kind in [StrategyKind::SmOb, StrategyKind::SmRc] {
        for threads in [1usize, 4] {
            let base = run_transact_with(&plat, kind, None, repl2(), cfg(threads, 60)).unwrap();
            let anchored = run_transact_concurrent(
                &plat,
                kind,
                repl2(),
                ConcurrencyConfig::default(),
                cfg(threads, 60),
            )
            .unwrap();
            let tag = format!("{kind:?} threads={threads}");
            assert_eq!(base.makespan, anchored.makespan, "{tag}: makespan");
            assert_eq!(base.txns, anchored.txns, "{tag}: txns");
            assert_eq!(base.writes, anchored.writes, "{tag}: writes");
            assert_eq!(base.epochs, anchored.epochs, "{tag}: epochs");
            assert_eq!(base.busy_ns, anchored.busy_ns, "{tag}: busy_ns");
            assert_eq!(base.doorbells, anchored.doorbells, "{tag}: doorbells");
            assert_eq!(base.posted_wqes, anchored.posted_wqes, "{tag}: posted_wqes");
            assert_eq!(base.wire_wqes, anchored.wire_wqes, "{tag}: wire_wqes");
            assert_eq!(base.per_thread, anchored.per_thread, "{tag}: per-thread times");
            assert_eq!(
                base.per_backup_horizon, anchored.per_backup_horizon,
                "{tag}: persist horizons"
            );
            // The counters count in both paths (window 0 = counter-only).
            assert_eq!(base.fences_issued, anchored.fences_issued, "{tag}: fences");
            assert_eq!(anchored.fence_piggybacks, 0, "{tag}: no window, no piggybacks");
            assert_eq!(anchored.pipeline_waits, 0, "{tag}: anchor bypasses pipelines");
            assert_eq!(anchored.pipeline_wait_ns, 0, "{tag}");
            assert_eq!(anchored.pipeline_occupancy(), 0.0, "{tag}");
        }
    }
}

#[test]
fn default_concurrency_pins_the_backup_ledgers() {
    // Ledger-level identity: the anchored mirror's replicated write
    // stream matches the legacy mirror's on every backup, event for
    // event (addresses, values, durability instants, coordinates).
    let plat = Platform::default();
    let mut base =
        Mirror::try_build(plat.clone(), StrategyKind::SmOb, None, repl2(), true).unwrap();
    let mut anchored =
        Mirror::try_build(plat.clone(), StrategyKind::SmOb, None, repl2(), true).unwrap();
    anchored.set_concurrency(ConcurrencyConfig::default());
    let c = cfg(4, 40);
    let ob = run_transact_on(&mut base, c);
    let oa = run_transact_on(&mut anchored, c);
    assert_eq!(ob.makespan, oa.makespan);
    for b in 0..2 {
        assert_eq!(
            base.backup(b).ledger.events(),
            anchored.backup(b).ledger.events(),
            "backup {b} ledger diverged"
        );
    }
}

// ---------------------------------------------------------------------------
// 2. Group-fence soundness: piggybacking saves work, never durability.
// ---------------------------------------------------------------------------

#[test]
fn piggybacked_fences_conserve_commit_count_and_cut_busy() {
    let plat = Platform::default();
    let serial = run_transact_concurrent(
        &plat,
        StrategyKind::SmOb,
        repl2(),
        ConcurrencyConfig::default(),
        cfg(4, 80),
    )
    .unwrap();
    let grouped = run_transact_concurrent(
        &plat,
        StrategyKind::SmOb,
        repl2(),
        ConcurrencyConfig::new(4, 2_600),
        cfg(4, 80),
    )
    .unwrap();
    assert_eq!(grouped.txns, serial.txns, "every txn must still commit");
    assert!(grouped.fence_piggybacks > 0, "contending threads must share fences");
    // SM-OB blocks exactly one fence per commit: piggybacks account for
    // every fence the grouped run did not issue.
    assert_eq!(
        grouped.fences_issued + grouped.fence_piggybacks,
        serial.fences_issued,
        "fence conservation"
    );
    assert!(grouped.fences_issued < serial.fences_issued);
    assert!(grouped.fences_per_txn() < serial.fences_per_txn());
    assert!(
        grouped.busy_ns < serial.busy_ns,
        "skipped verb posts must show up as saved CPU: {} vs {}",
        grouped.busy_ns,
        serial.busy_ns
    );
    // fences_issued <= txns_committed — the CI-gated counter invariant.
    assert!(grouped.fences_issued <= grouped.txns);
}

#[test]
fn piggybacked_fences_do_not_weaken_durability() {
    // A grouped run must replicate the same number of line writes to
    // every backup, and the backup images at their persist horizons must
    // cover the primary image — piggybacking elides requester verbs,
    // not responder persistence.
    let plat = Platform::default();
    let drive = |conc: ConcurrencyConfig| {
        let mut m =
            Mirror::try_build(plat.clone(), StrategyKind::SmOb, None, repl2(), true).unwrap();
        m.set_concurrency(conc);
        let out = run_transact_on(&mut m, cfg(4, 40));
        (m, out)
    };
    let (serial_m, serial_out) = drive(ConcurrencyConfig::default());
    let (grouped_m, grouped_out) = drive(ConcurrencyConfig::new(4, 2_600));
    assert!(grouped_out.fence_piggybacks > 0);
    for b in 0..2 {
        let s = &serial_m.backup(b).ledger;
        let g = &grouped_m.backup(b).ledger;
        assert_eq!(s.len(), g.len(), "backup {b}: replicated write count changed");
        // Same data stream: identical (addr, val) multiset per thread
        // order; only durability instants may shift.
        let key = |l: &DurabilityLog| {
            let mut v: Vec<(u32, u64, Addr, u64)> = l
                .events()
                .iter()
                .map(|e| (e.thread, e.seq, e.addr, e.val))
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(key(s), key(g), "backup {b}: data stream diverged");
        // The backup image at its horizon covers the primary image.
        let img = g.image_at(g.horizon());
        for (a, v) in grouped_m.image().iter() {
            assert_eq!(img.get(a), Some(v), "backup {b}: line {a:#x} lost");
        }
    }
    // Per-txn durability acks still cover every backup's horizon.
    for (b, &h) in grouped_out.per_backup_horizon.iter().enumerate() {
        assert!(h > 0, "backup {b} never persisted");
    }
}

#[test]
fn pipeline_counters_track_commit_fan_out() {
    let plat = Platform::default();
    let at = |p: usize| {
        run_transact_concurrent(
            &plat,
            StrategyKind::SmOb,
            repl2(),
            ConcurrencyConfig::new(p, 2_600),
            cfg(4, 60),
        )
        .unwrap()
    };
    let narrow = at(1);
    let wide = at(4);
    assert_eq!(narrow.commit_pipelines, 1);
    assert_eq!(wide.commit_pipelines, 4);
    assert!(narrow.pipeline_waits > 0, "P=1 must queue contending commits");
    assert!(
        wide.pipeline_wait_ns < narrow.pipeline_wait_ns,
        "widening the fan-out must cut queueing: {} vs {}",
        wide.pipeline_wait_ns,
        narrow.pipeline_wait_ns
    );
    assert!(narrow.pipeline_occupancy() > 0.0 && narrow.pipeline_occupancy() <= 1.0);
}

// ---------------------------------------------------------------------------
// 3. Detectable-op crash recovery: kill at every durable instant, replay,
//    and land on the uninterrupted run's image + replicated sequence.
// ---------------------------------------------------------------------------

/// Durable data regions the recovery comparison covers: heap + roots.
/// The log region (consumed by rollback) and the checkpoint region
/// (partially-announced ops are *expected* to differ pre-replay) are
/// bookkeeping, not payload.
fn data_region(addr: Addr) -> bool {
    (REGION_HEAP..REGION_LOGS).contains(&addr) || (REGION_ROOTS..REGION_CKPT).contains(&addr)
}

/// PM images compare with absent-means-zero semantics (a rolled-back
/// first write restores the line to 0; the golden image simply never
/// mentions it).
fn assert_images_match(got: &HashMap<Addr, u64>, want: &HashMap<Addr, u64>, tag: &str) {
    for addr in got.keys().chain(want.keys()) {
        if !data_region(*addr) {
            continue;
        }
        let g = got.get(addr).copied().unwrap_or(0);
        let w = want.get(addr).copied().unwrap_or(0);
        assert_eq!(g, w, "{tag}: line {addr:#x} diverged (got {g}, want {w})");
    }
}

/// Drive `golden` (a sequence of detectable ops on a ledgered mirror,
/// returning the per-op completion instants), then for EVERY durable
/// event time in the backup ledger: reconstruct the crash image, run
/// recovery (rollback -> checkpoint -> optional `replay`), and check the
/// result against the uninterrupted run — both the durable data image
/// and, for re-executed ops, the exact replicated (addr, val) sequence.
fn check_crash_replay(
    golden: impl Fn(&mut Mirror, &mut ThreadCtx) -> Vec<Ns>,
    replay: impl Fn(&mut Mirror, &mut ThreadCtx, &Checkpoint),
) {
    let plat = Platform::default();
    let mut gm = Mirror::new(plat.clone(), StrategyKind::SmOb, true);
    let mut gt = ThreadCtx::new(0);
    let boundaries = golden(&mut gm, &mut gt);
    let ledger = gm.backup(0).ledger.clone();
    assert!(ledger.horizon() > 0, "golden run replicated nothing");

    // bounds[s] = instant op `s` was complete (s = 0: before any op);
    // expected[s] = the durable data image at that instant.
    let mut bounds: Vec<Ns> = vec![0];
    bounds.extend(&boundaries);
    let expected: Vec<HashMap<Addr, u64>> =
        bounds.iter().map(|&b| ledger.image_at(b)).collect();
    let log = log_base_for(0);

    let mut crash_times: Vec<Ns> = ledger.events().iter().map(|e| e.at).collect();
    crash_times.push(0);
    crash_times.sort_unstable();
    crash_times.dedup();

    let mut replays = 0usize;
    let mut completes = 0usize;
    for &t_crash in &crash_times {
        let mut img = ledger.image_at(t_crash);
        // Recovery step 1: roll back the active undo log FIRST, so a
        // torn commit's done stamp reverts with the rest of its txn.
        rollback_in_image(&mut img, log);
        // Recovery step 2: the checkpoint now decides.
        let ck = read_checkpoint(&img, 0);
        assert!(
            (ck.seq as usize) < expected.len(),
            "crash@{t_crash}: checkpoint seq {} out of range",
            ck.seq
        );
        if !ck.needs_replay() {
            completes += 1;
            assert_images_match(
                &img,
                &expected[ck.seq as usize],
                &format!("crash@{t_crash} (complete, seq {})", ck.seq),
            );
            continue;
        }
        replays += 1;
        let s = ck.seq as usize;
        // Recovery step 3: re-execute op `seq` from the checkpointed
        // arguments on a fresh mirror preloaded with the crash image.
        let mut rm = Mirror::new(plat.clone(), StrategyKind::SmOb, true);
        let mut rt = ThreadCtx::new(0);
        for (&a, &v) in &img {
            rm.store(&mut rt, a, v);
        }
        replay(&mut rm, &mut rt, &ck);
        let final_img: HashMap<Addr, u64> =
            rm.image().iter().map(|(&a, &v)| (a, v)).collect();
        assert_images_match(
            &final_img,
            &expected[s],
            &format!("crash@{t_crash} (replayed seq {})", ck.seq),
        );
        // The replayed op must replicate exactly the golden op's write
        // sequence: same (addr, val) lines in the same issue order.
        let mut want: Vec<&DurEvent> = ledger
            .events()
            .iter()
            .filter(|e| e.at > bounds[s - 1] && e.at <= bounds[s])
            .collect();
        want.sort_unstable_by_key(|e| e.seq);
        let mut got: Vec<&DurEvent> = rm.backup(0).ledger.events().iter().collect();
        got.sort_unstable_by_key(|e| e.seq);
        assert_eq!(
            want.len(),
            got.len(),
            "crash@{t_crash}: replay of seq {} replicated {} writes, golden did {}",
            ck.seq,
            got.len(),
            want.len()
        );
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(
                (w.addr, w.val),
                (g.addr, g.val),
                "crash@{t_crash}: replay of seq {} diverged at write #{}",
                ck.seq,
                w.seq
            );
        }
    }
    assert!(replays > 0, "no crash instant exercised a replay");
    assert!(completes > 0, "no crash instant found a completed op");
}

#[test]
fn cbtree_replays_to_the_same_image_from_any_crash_point() {
    // Mix of fresh keys (empty-root install + splice paths) and repeats
    // (update-in-place path).
    const OPS: [(u64, u64); 12] = [
        (5, 50),
        (9, 90),
        (5, 51),
        (12, 120),
        (3, 30),
        (9, 91),
        (7, 70),
        (1, 10),
        (5, 52),
        (30, 300),
        (2, 20),
        (12, 121),
    ];
    check_crash_replay(
        |m, t| {
            let mut heap = PmHeap::new();
            let mut tree = CritBitTree::new(0);
            let mut ctx = DetectCtx::new(0, 1);
            let log = log_base_for(0);
            OPS.iter()
                .map(|&(k, v)| {
                    tree_insert(&mut tree, m, t, &mut heap, &mut ctx, k, v, log);
                    t.now()
                })
                .collect()
        },
        |m, t, ck| {
            assert_eq!(ck.opcode, OP_TREE_INSERT);
            // Bump-only allocation from the checkpointed watermark makes
            // the replay address-deterministic.
            let mut heap = PmHeap::at_mark(ck.mark);
            let mut tree = CritBitTree::new(0);
            let mut ctx = DetectCtx::resume(0, 1, ck.seq - 1);
            tree_insert(&mut tree, m, t, &mut heap, &mut ctx, ck.key, ck.val, log_base_for(0));
        },
    );
}

#[test]
fn hashmap_replays_to_the_same_image_from_any_crash_point() {
    const OPS: [(u64, u64); 10] = [
        (1, 100),
        (2, 200),
        (17, 170), // collides with 1 mod 16
        (1, 101),
        (4, 400),
        (33, 330),
        (2, 201),
        (8, 800),
        (17, 171),
        (6, 600),
    ];
    check_crash_replay(
        |m, t| {
            let mut heap = PmHeap::new();
            let mut map = PHashMap::create(&mut heap, 16);
            let mut ctx = DetectCtx::new(0, 1);
            let log = log_base_for(0);
            OPS.iter()
                .map(|&(k, v)| {
                    map_put(&mut map, m, t, &mut heap, &mut ctx, k, v, log);
                    t.now()
                })
                .collect()
        },
        |m, t, ck| {
            assert_eq!(ck.opcode, OP_MAP_PUT);
            // Recreate the handle the way the golden run did — the
            // bucket-array alloc is the heap's first, so the address is
            // deterministic — THEN jump the heap to the checkpointed
            // watermark for the replayed op's node allocations.
            let mut heap = PmHeap::new();
            let mut map = PHashMap::create(&mut heap, 16);
            let mut heap = PmHeap::at_mark(ck.mark);
            let mut ctx = DetectCtx::resume(0, 1, ck.seq - 1);
            map_put(&mut map, m, t, &mut heap, &mut ctx, ck.key, ck.val, log_base_for(0));
        },
    );
}

#[test]
fn kvstore_batches_replay_to_the_same_image_from_any_crash_point() {
    // Echo batches: the whole batch is the checkpointed payload, so a
    // replay re-applies exactly the lost client updates.
    let batches: Vec<Vec<(u64, u64)>> = vec![
        vec![(1, 10), (2, 20), (3, 30)],
        vec![(1, 11), (4, 40)],
        vec![(5, 50), (2, 21), (6, 60)],
        vec![(7, 70)],
    ];
    let golden_batches = batches.clone();
    check_crash_replay(
        move |m, t| {
            let mut heap = PmHeap::new();
            let mut kv = KvStore::create(&mut heap, 16, 0);
            let mut ctx = DetectCtx::new(0, 1);
            let log = log_base_for(0);
            golden_batches
                .iter()
                .map(|b| {
                    kv_apply_batch(&mut kv, m, t, &mut heap, &mut ctx, b, log);
                    t.now()
                })
                .collect()
        },
        |m, t, ck| {
            assert_eq!(ck.opcode, OP_KV_BATCH);
            assert_eq!(ck.batch.len(), ck.key as usize, "payload length stamp");
            let mut heap = PmHeap::new();
            let mut kv = KvStore::create(&mut heap, 16, 0);
            let mut heap = PmHeap::at_mark(ck.mark);
            let mut ctx = DetectCtx::resume(0, 1, ck.seq - 1);
            kv_apply_batch(&mut kv, m, t, &mut heap, &mut ctx, &ck.batch, log_base_for(0));
        },
    );
}
