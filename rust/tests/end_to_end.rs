//! End-to-end integration: full workloads through the whole stack, with
//! paper-shape assertions (the executable form of EXPERIMENTS.md).

use pmsm::config::{Platform, StrategyKind};
use pmsm::coordinator::{Mirror, ThreadCtx};
use pmsm::pstore::{log_base_for, CritBitTree, PmHeap};
use pmsm::recovery::{self, TxnHistory};
use pmsm::txn::Txn;
use pmsm::workloads::{run_transact, run_whisper, TransactConfig, WhisperApp, WhisperConfig};
use std::collections::HashMap;

fn slow(plat: &Platform, kind: StrategyKind, e: u32, w: u32, txns: u64) -> f64 {
    let cfg = TransactConfig {
        epochs: e,
        writes: w,
        txns,
        ..Default::default()
    };
    let base = run_transact(plat, StrategyKind::NoSm, cfg).makespan as f64;
    run_transact(plat, kind, cfg).makespan as f64 / base
}

#[test]
fn f4_rc_band_and_amortization() {
    // Paper Fig. 4: SM-RC slowdowns ~20x-55x+, worst at w=1, easing with w.
    let p = Platform::default();
    let rc_w1 = slow(&p, StrategyKind::SmRc, 4, 1, 400);
    let rc_w8 = slow(&p, StrategyKind::SmRc, 4, 8, 100);
    assert!(rc_w1 > 20.0, "RC 4-1 = {rc_w1}");
    assert!(rc_w1 < 100.0, "RC 4-1 = {rc_w1}");
    assert!(rc_w8 < rc_w1 / 2.0, "amortization: w1={rc_w1} w8={rc_w8}");
}

#[test]
fn f4_ob_dd_beat_rc_everywhere() {
    let p = Platform::default();
    for (e, w) in [(1u32, 1u32), (4, 1), (16, 2), (64, 4), (256, 8)] {
        let txns = (4000 / (e as u64 * w as u64)).max(20);
        let rc = slow(&p, StrategyKind::SmRc, e, w, txns);
        let ob = slow(&p, StrategyKind::SmOb, e, w, txns);
        let dd = slow(&p, StrategyKind::SmDd, e, w, txns);
        assert!(rc >= ob, "{e}-{w}: rc={rc} ob={ob}");
        assert!(rc >= dd, "{e}-{w}: rc={rc} dd={dd}");
    }
}

#[test]
fn f4_crossover_dd_small_ob_large() {
    let p = Platform::default();
    let dd4 = slow(&p, StrategyKind::SmDd, 4, 1, 500);
    let ob4 = slow(&p, StrategyKind::SmOb, 4, 1, 500);
    let dd256 = slow(&p, StrategyKind::SmDd, 256, 1, 30);
    let ob256 = slow(&p, StrategyKind::SmOb, 256, 1, 30);
    assert!(dd4 <= ob4 * 1.05, "DD should win small: dd={dd4} ob={ob4}");
    assert!(ob256 < dd256, "OB should win large: ob={ob256} dd={dd256}");
}

#[test]
fn f5_whisper_rc_worst_and_in_band() {
    // Paper Fig. 5 / H1: RC is the worst strategy on every app; overall
    // overhead magnitudes land in the paper's neighbourhood.
    let p = Platform::default();
    let mut rc_ratios = Vec::new();
    for app in WhisperApp::ALL {
        let ops = if app == WhisperApp::Echo { 30 } else { 250 };
        let cfg = WhisperConfig {
            app,
            ops,
            threads: 4,
            seed: 42,
        };
        let base = run_whisper(&p, StrategyKind::NoSm, cfg).makespan as f64;
        let rc = run_whisper(&p, StrategyKind::SmRc, cfg).makespan as f64 / base;
        let ob = run_whisper(&p, StrategyKind::SmOb, cfg).makespan as f64 / base;
        let dd = run_whisper(&p, StrategyKind::SmDd, cfg).makespan as f64 / base;
        assert!(rc > ob, "{app}: rc={rc} ob={ob}");
        assert!(rc > dd, "{app}: rc={rc} dd={dd}");
        assert!(rc > 2.0, "{app}: rc={rc} too low");
        rc_ratios.push(rc);
    }
    let geo = pmsm::util::stats::geomean(&rc_ratios);
    assert!(
        (3.0..15.0).contains(&geo),
        "RC geomean {geo} out of paper band (paper: 6.7x)"
    );
}

#[test]
fn whisper_trace_shapes_match_paper() {
    // Paper §7.2: ~1.4-2 writes/epoch; epochs/txn from ~5 (hashmap) to
    // 300+ (echo).
    let p = Platform::default();
    let mut ept = HashMap::new();
    for app in WhisperApp::ALL {
        let ops = if app == WhisperApp::Echo { 30 } else { 200 };
        let out = run_whisper(
            &p,
            StrategyKind::NoSm,
            WhisperConfig {
                app,
                ops,
                threads: 2,
                seed: 7,
            },
        );
        let wpe = out.writes_per_epoch();
        assert!((0.8..2.5).contains(&wpe), "{app}: writes/epoch {wpe}");
        ept.insert(app, out.epochs_per_txn());
    }
    assert!(ept[&WhisperApp::Echo] > 100.0, "echo: {}", ept[&WhisperApp::Echo]);
    assert!(ept[&WhisperApp::Hashmap] < 20.0);
    assert!(ept[&WhisperApp::Echo] > 5.0 * ept[&WhisperApp::Hashmap]);
}

#[test]
fn crash_recovery_on_real_data_structure() {
    // Drive a crit-bit tree under each SM strategy, then verify failure
    // atomicity + durability for every crash point in the ledger.
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        let mut m = Mirror::new(Platform::default(), kind, true);
        let mut t = ThreadCtx::new(0);
        let mut heap = PmHeap::new();
        let mut tree = CritBitTree::new(0);
        let log = log_base_for(0);

        // Track golden data-addr snapshots per committed txn. The tree's
        // own addresses vary; track the full primary image restricted to
        // non-log lines.
        let mut hist = TxnHistory::new(HashMap::new());
        let mut data_addrs: Vec<u64> = Vec::new();
        for i in 0..10u64 {
            tree.insert(&mut m, &mut t, &mut heap, i * 3, 100 + i, log, None);
            let snap: HashMap<u64, u64> = m
                .image()
                .iter()
                .filter(|(a, _)| **a < log || **a >= log + 0x10_0000)
                .map(|(a, v)| (*a, *v))
                .collect();
            for a in snap.keys() {
                if !data_addrs.contains(a) {
                    data_addrs.push(*a);
                }
            }
            hist.commit(snap, t.last_dfence);
        }
        let checked = recovery::check_all_crashes(
            &m.backup(0).ledger,
            &hist,
            &[log],
            &data_addrs,
        )
        .unwrap_or_else(|e| panic!("{kind}: {e}"));
        assert!(checked > 50, "{kind}: only {checked} crash points");
        recovery::check_epoch_ordering(&m.backup(0).ledger)
            .unwrap_or_else(|e| panic!("{kind}: {e}"));
    }
}

#[test]
fn multithreaded_epoch_ordering_invariant() {
    // 4 threads of undo transactions; the per-thread epoch ordering
    // invariant must hold on the shared backup under every strategy.
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        let mut m = Mirror::new(Platform::default(), kind, true);
        let mut sources: Vec<Box<dyn pmsm::coordinator::sched::TxnSource>> = (0..4)
            .map(|th| {
                let mut i = 0u64;
                let log = log_base_for(th);
                let base = 0x9000_0000u64 + th as u64 * 0x10000;
                Box::new(move |m: &mut Mirror, t: &mut ThreadCtx| {
                    if i >= 15 {
                        return false;
                    }
                    let mut tx = Txn::begin(m, t, log, None);
                    tx.write(m, t, base + (i % 4) * 64, i);
                    tx.write(m, t, base + 0x1000 + (i % 2) * 64, i);
                    tx.commit(m, t);
                    i += 1;
                    true
                }) as Box<dyn pmsm::coordinator::sched::TxnSource>
            })
            .collect();
        pmsm::coordinator::sched::run_threads(&mut m, &mut sources);
        recovery::check_epoch_ordering(&m.backup(0).ledger)
            .unwrap_or_else(|e| panic!("{kind}: {e}"));
        assert_eq!(m.backup(0).ledger.len() > 0, true);
    }
}

#[test]
fn dfence_horizon_invariant_all_strategies() {
    // Guarantee-2 at the coordinator level: after every transaction's
    // dfence, the thread's clock is past every persist it caused.
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        let mut m = Mirror::new(Platform::default(), kind, true);
        let mut t = ThreadCtx::new(0);
        for i in 0..20u64 {
            m.txn_begin(&mut t, None);
            for e in 0..3 {
                let addr = 0x5000_0000 + ((i * 3 + e) % 7) * 64;
                m.store(&mut t, addr, i);
                m.clwb(&mut t, addr);
                m.sfence(&mut t);
            }
            m.txn_commit(&mut t);
            let horizon = m.backup(0).persist_horizon();
            assert!(
                t.last_dfence >= horizon,
                "{kind} txn {i}: dfence {} < horizon {}",
                t.last_dfence,
                horizon
            );
        }
    }
}
