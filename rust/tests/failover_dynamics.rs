//! Failover & rejoin dynamics, end to end: the fault matrix (kill each
//! backup index at early/mid/late points under every ack policy),
//! halt-mode stalls at the kill point, catch-up resync of a rejoining
//! backup, and the recovery edge cases that only appear with dynamic
//! membership.

use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{Mirror, ThreadCtx};
use pmsm::net::{BackupState, FaultsConfig, OnLoss};
use pmsm::pstore::log_base_for;
use pmsm::recovery::{
    check_faulted_group_crashes, check_group_crashes, check_group_epoch_ordering,
    TxnHistory,
};
use pmsm::txn::Txn;
use std::collections::HashMap;

const D0: u64 = 0x7000_0000;
const D1: u64 = 0x7000_0040;

fn faults(plan: &str, on_loss: OnLoss) -> FaultsConfig {
    FaultsConfig::with_plan(plan, on_loss).expect("valid plan")
}

fn build(policy: AckPolicy, f: FaultsConfig) -> Mirror {
    Mirror::try_build_faulted(
        Platform::default(),
        StrategyKind::SmOb,
        None,
        ReplicationConfig::new(3, policy),
        f,
        true,
    )
    .expect("valid build")
}

/// Drive `n` two-write txns, recording history; stops early (returning
/// the partial history) if the fabric stalls.
fn drive_txns(m: &mut Mirror, t: &mut ThreadCtx, n: u64) -> TxnHistory {
    let log = log_base_for(0);
    let mut hist = TxnHistory::new(HashMap::new());
    for i in 0..n {
        let mut tx = Txn::begin(m, t, log, None);
        tx.write(m, t, D0, 100 + i);
        tx.write(m, t, D1, 200 + i);
        tx.commit(m, t);
        if m.stall().is_some() {
            break;
        }
        let mut snap = HashMap::new();
        snap.insert(D0, 100 + i);
        snap.insert(D1, 200 + i);
        hist.commit(snap, t.last_dfence);
    }
    hist
}

/// Fault-free span of the standard workload, used to place kill points.
fn baseline_span(n: u64) -> u64 {
    let mut m = build(AckPolicy::All, FaultsConfig::default());
    let mut t = ThreadCtx::new(0);
    drive_txns(&mut m, &mut t, n);
    t.now()
}

/// The fault matrix: kill each backup index at an early/mid/late point
/// under each ack policy, run to completion in degrade mode, then check
/// recovery from the *surviving* ledgers with the policy's static
/// requirement — it must succeed exactly when enough replicas survive
/// (quorum:2 / majority of 3 → 2 survivors suffice) and return a checked
/// error otherwise (all → 3 required, only 2 survive).
#[test]
fn fault_matrix_kill_each_backup_each_phase() {
    const TXNS: u64 = 6;
    let span = baseline_span(TXNS);
    let log = log_base_for(0);
    for policy in [AckPolicy::All, AckPolicy::Majority, AckPolicy::Quorum(2)] {
        let required = ReplicationConfig::new(3, policy).required();
        for victim in 0..3usize {
            for (num, den) in [(1u64, 8u64), (1, 2), (7, 8)] {
                let kill_at = span * num / den;
                let mut m = build(
                    policy,
                    faults(&format!("kill:{victim}@{kill_at}"), OnLoss::Degrade),
                );
                let mut t = ThreadCtx::new(0);
                let hist = drive_txns(&mut m, &mut t, TXNS);
                assert!(
                    m.stall().is_none(),
                    "{policy}/kill {victim}@{num}/{den}: degrade must not stall"
                );
                assert_eq!(
                    hist.committed(),
                    TXNS as usize,
                    "{policy}/kill {victim}@{num}/{den}: run must complete"
                );
                m.settle(t.now());
                let ledgers = m.fabric().ledgers();
                check_group_epoch_ordering(&ledgers).unwrap();
                let survivors: Vec<_> = (0..3)
                    .filter(|&b| b != victim)
                    .map(|b| ledgers[b])
                    .collect();
                let result = check_group_crashes(
                    &survivors,
                    &hist,
                    &[log],
                    &[D0, D1],
                    required,
                );
                if required <= survivors.len() {
                    let checked = result.unwrap_or_else(|e| {
                        panic!("{policy}/kill {victim}@{num}/{den}: {e}")
                    });
                    assert!(checked > 10, "{policy}: only {checked} crash points");
                } else {
                    assert!(
                        result.is_err(),
                        "{policy}/kill {victim}@{num}/{den}: {required} required \
                         but only {} survive — must be a checked error",
                        survivors.len()
                    );
                }
            }
        }
    }
}

/// Acceptance scenario, halt side: `backups = 3, ack = all, on_loss =
/// halt` with a mid-run kill stops at the kill point with a reported
/// stall; the exact same run under `quorum:2` completes and recovers
/// from the two survivors via the fault-aware sweep.
#[test]
fn halt_stops_at_kill_point_quorum_completes() {
    const TXNS: u64 = 6;
    let span = baseline_span(TXNS);
    let kill_at = span / 2;
    let plan = format!("kill:1@{kill_at}");

    // all + halt: stall at the kill point.
    let mut m = build(AckPolicy::All, faults(&plan, OnLoss::Halt));
    let mut t = ThreadCtx::new(0);
    let hist = drive_txns(&mut m, &mut t, TXNS);
    let stall = *m.stall().expect("all + halt must stall");
    assert!(stall.at >= kill_at, "stalled at {} before the kill", stall.at);
    assert_eq!(stall.required, 3);
    assert_eq!(stall.alive, 2);
    assert!(
        (hist.committed() as u64) < TXNS,
        "the halted run must abandon transactions"
    );
    // Every transaction acked before the stall is durable on EVERY
    // backup (the all-policy never weakened).
    let ledgers = m.fabric().ledgers();
    check_group_crashes(&ledgers, &hist, &[log_base_for(0)], &[D0, D1], 3)
        .expect("acked prefix must be fully replicated");

    // quorum:2 + halt: completes and recovers from the survivors.
    let mut m = build(AckPolicy::Quorum(2), faults(&plan, OnLoss::Halt));
    let mut t = ThreadCtx::new(0);
    let hist = drive_txns(&mut m, &mut t, TXNS);
    assert!(m.stall().is_none(), "quorum:2 tolerates one loss");
    assert_eq!(hist.committed(), TXNS as usize);
    m.settle(t.now());
    let checked = check_faulted_group_crashes(
        &m.fabric().ledgers(),
        &hist,
        &[log_base_for(0)],
        &[D0, D1],
        2,
        OnLoss::Halt,
        &m.fabric().timeline(),
    )
    .expect("two survivors satisfy quorum:2");
    assert!(checked > 10);
}

/// A killed backup that rejoins resyncs the missed suffix from a peer
/// and re-enters the quorum: ledgers converge, the epoch invariant holds
/// on the replayed ledger, and the fault-aware sweep accepts the
/// diverged-then-healed prefix across the outage window.
#[test]
fn rejoin_resyncs_and_reenters_quorum() {
    const TXNS: u64 = 10;
    let span = baseline_span(TXNS);
    let kill_at = span / 4;
    let rejoin_at = span / 2;
    let plan = format!("kill:2@{kill_at},rejoin:2@{rejoin_at}");
    let mut m = build(AckPolicy::Quorum(2), faults(&plan, OnLoss::Halt));
    let mut t = ThreadCtx::new(0);
    let hist = drive_txns(&mut m, &mut t, TXNS);
    assert!(m.stall().is_none());
    assert_eq!(hist.committed(), TXNS as usize);
    // Settle beyond any pending resync completion so the backup is back.
    m.settle(t.now().max(rejoin_at + 10_000_000));
    assert_eq!(m.fabric().state(2), BackupState::Alive, "must re-enter");
    let stats = m.fabric().backup_stats();
    assert_eq!(stats[2].resyncs, 1);
    assert!(stats[2].resync_lines > 0, "missed suffix must be streamed");
    assert!(stats[2].dead_ns > 0);
    assert!(stats[2].last_handoff_ns >= m.fabric().faults().handoff_ns);
    assert_eq!(stats[0].resyncs, 0);
    // Ledgers converge to the same event count.
    let ledgers = m.fabric().ledgers();
    assert_eq!(ledgers[2].len(), ledgers[0].len(), "resync must close the gap");
    check_group_epoch_ordering(&ledgers).unwrap();
    let checked = check_faulted_group_crashes(
        &ledgers,
        &hist,
        &[log_base_for(0)],
        &[D0, D1],
        2,
        OnLoss::Halt,
        &m.fabric().timeline(),
    )
    .expect("dead-then-rejoined ledger must pass the fault-aware sweep");
    assert!(checked > 10);
    // The timeline recorded the whole round trip: down, then up again.
    let tl = m.fabric().timeline();
    assert_eq!(tl.alive_count_at(kill_at), 2);
    assert_eq!(tl.alive_count_at(u64::MAX), 3);
}

/// Edge case: a backup that dies and rejoins before the first write has
/// nothing to resync; the run is indistinguishable from fault-free.
#[test]
fn rejoin_before_any_write_is_a_noop_resync() {
    let mut f = faults("kill:1@0,rejoin:1@1", OnLoss::Halt);
    f.handoff_ns = 5; // the resync window closes before the first write
    let mut m = build(AckPolicy::All, f);
    let mut t = ThreadCtx::new(0);
    // Idle past the resync window before touching PM.
    m.compute(&mut t, 1_000);
    let hist = drive_txns(&mut m, &mut t, 3);
    assert!(m.stall().is_none(), "backup is back before any write");
    assert_eq!(hist.committed(), 3);
    assert_eq!(m.fabric().state(1), BackupState::Alive);
    let stats = m.fabric().backup_stats();
    assert_eq!(stats[1].resync_lines, 0, "nothing to stream");
    assert_eq!(stats[1].resyncs, 1);
    // All three ledgers identical: the outage predates every write.
    let ledgers = m.fabric().ledgers();
    assert_eq!(ledgers[1].len(), ledgers[0].len());
    check_group_crashes(&ledgers, &hist, &[log_base_for(0)], &[D0, D1], 3)
        .expect("full group durability holds");
}

/// Edge case: killing every backup stalls even in degrade mode — a
/// fully dead group can never ack a durability fence.
#[test]
fn all_backups_dead_stalls_in_any_mode() {
    for mode in [OnLoss::Halt, OnLoss::Degrade] {
        let mut m = build(
            AckPolicy::Quorum(1),
            faults("kill:0@0,kill:1@0,kill:2@0", mode),
        );
        let mut t = ThreadCtx::new(0);
        let hist = drive_txns(&mut m, &mut t, 3);
        let stall = m.stall().unwrap_or_else(|| panic!("{mode}: no stall"));
        assert_eq!(stall.alive, 0, "{mode}");
        assert_eq!(hist.committed(), 0, "{mode}: nothing durably acked");
    }
}

/// A degraded `all` group keeps group durability on the survivors: after
/// the kill the fence covers both remaining backups, so recovery with
/// the loss-adjusted requirement passes across the whole run.
#[test]
fn degraded_all_keeps_survivor_durability() {
    const TXNS: u64 = 6;
    let span = baseline_span(TXNS);
    let plan = format!("kill:0@{}", span / 3);
    let mut m = build(AckPolicy::All, faults(&plan, OnLoss::Degrade));
    let mut t = ThreadCtx::new(0);
    let hist = drive_txns(&mut m, &mut t, TXNS);
    assert_eq!(hist.committed(), TXNS as usize);
    m.settle(t.now());
    let checked = check_faulted_group_crashes(
        &m.fabric().ledgers(),
        &hist,
        &[log_base_for(0)],
        &[D0, D1],
        3,
        OnLoss::Degrade,
        &m.fabric().timeline(),
    )
    .expect("degraded all must still cover the survivors");
    assert!(checked > 10);
}
