//! Primary failover acceptance tests: the kill-time x ack-policy x
//! shard-count matrix (the primary dies early/mid/late and the
//! membership layer must seat a successor), leader completeness of the
//! elected primary at every membership epoch, the demoted primary's
//! rejoin-as-backup path, the SM-RC rejection of `rejoin:p`, and the
//! anchor: a plan with no primary faults leaves the membership
//! machinery a guard-clause pass-through, event-for-event identical to
//! the pre-membership path.

use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{Mirror, ShardMapSpec, ShardingConfig, ThreadCtx};
use pmsm::net::{BackupState, FaultsConfig, OnLoss};
use pmsm::pstore::log_base_for;
use pmsm::recovery::{self, TxnHistory};
use pmsm::txn::Txn;
use pmsm::workloads::transact::run_transact_on;
use pmsm::workloads::TransactConfig;
use std::collections::HashMap;

// Two adjacent data lines: under the modulo map they land on different
// shards, so every multi-shard run exercises cross-shard failover.
const D0: u64 = 0x20_0000;
const D1: u64 = 0x20_0040;

fn faults(plan: &str, on_loss: OnLoss) -> FaultsConfig {
    FaultsConfig::with_plan(plan, on_loss).expect("valid plan")
}

fn build(policy: AckPolicy, f: FaultsConfig, shards: usize) -> Mirror {
    Mirror::try_build_sharded(
        Platform::default(),
        StrategyKind::SmOb,
        None,
        ReplicationConfig::new(3, policy),
        f,
        ShardingConfig::new(shards, ShardMapSpec::Modulo),
        true,
    )
    .expect("valid build")
}

/// Drive `n` two-write txns, recording history; stops early (returning
/// the partial history) if the fabric stalls.
fn drive_txns(m: &mut Mirror, t: &mut ThreadCtx, n: u64) -> TxnHistory {
    let log = log_base_for(0);
    let mut hist = TxnHistory::new(HashMap::new());
    for i in 0..n {
        let mut tx = Txn::begin(m, t, log, None);
        tx.write(m, t, D0, 100 + i);
        tx.write(m, t, D1, 200 + i);
        tx.commit(m, t);
        if m.stall().is_some() {
            break;
        }
        let mut snap = HashMap::new();
        snap.insert(D0, 100 + i);
        snap.insert(D1, 200 + i);
        hist.commit(snap, t.last_dfence);
    }
    hist
}

/// Fault-free span of the standard workload under a given shape, used
/// to place kill points.
fn baseline_span(policy: AckPolicy, shards: usize, n: u64) -> u64 {
    let mut m = build(policy, FaultsConfig::default(), shards);
    let mut t = ThreadCtx::new(0);
    drive_txns(&mut m, &mut t, n);
    t.now()
}

/// The matrix: kill the primary at an early/mid/late point under each
/// ack policy, on 1 and 4 shards. Policies that tolerate the loss of
/// one group member complete through the failover and satisfy leader
/// completeness at the recorded epoch; `all + halt` fails over and then
/// stalls at the next durability fence — the elected winner left the
/// backup group, so only 2 of the 3 required acks remain.
#[test]
fn primary_fault_matrix_kill_each_phase() {
    const TXNS: u64 = 8;
    let log = log_base_for(0);
    for (policy, on_loss, survives) in [
        (AckPolicy::All, OnLoss::Degrade, true),
        (AckPolicy::All, OnLoss::Halt, false),
        (AckPolicy::Majority, OnLoss::Halt, true),
        (AckPolicy::Quorum(2), OnLoss::Halt, true),
    ] {
        for shards in [1usize, 4] {
            let span = baseline_span(policy, shards, TXNS);
            for (num, den) in [(1u64, 8u64), (1, 2), (3, 4)] {
                let kill_at = span * num / den;
                let plan = format!("kill:p@{kill_at}");
                let mut m = build(policy, faults(&plan, on_loss), shards);
                let mut t = ThreadCtx::new(0);
                let hist = drive_txns(&mut m, &mut t, TXNS);
                m.settle(t.now());
                let tag = format!("{policy}/{on_loss}/shards={shards}/kill@{num}/{den}");
                assert_eq!(m.membership_epochs(), 1, "{tag}: exactly one failover");
                assert!(m.failover_downtime_ns() > 0, "{tag}: handoff is never free");
                // Synchronous fan-out keeps the alive peers' certified
                // prefixes in lockstep, so the election is a tie broken
                // to the lowest id and the winner has nothing to stream.
                assert_eq!(
                    m.rereplicated_lines(),
                    0,
                    "{tag}: converged peers need no re-replication"
                );
                for s in 0..shards {
                    assert_eq!(
                        m.shard_fabric(s).primary_slot(),
                        Some(0),
                        "{tag}: shard {s} must seat the one cross-shard winner"
                    );
                }
                if survives {
                    assert!(m.stall().is_none(), "{tag}: must ride through");
                    assert_eq!(hist.committed(), TXNS as usize, "{tag}: full run");
                    let checked = recovery::check_sharded_leader_completeness(
                        &m.shard_ledgers(),
                        &m.timelines(),
                        &hist,
                        &[log],
                        &[D0, D1],
                    )
                    .unwrap_or_else(|e| panic!("{tag}: {e}"));
                    assert_eq!(checked, 1, "{tag}: one epoch verified");
                    recovery::check_sharded_group_crashes(
                        &m.shard_ledgers(),
                        &m.timelines(),
                        &hist,
                        &[log],
                        &[D0, D1],
                        ReplicationConfig::new(3, policy).required(),
                        on_loss,
                        m.shard_map(),
                    )
                    .unwrap_or_else(|e| panic!("{tag}: {e}"));
                } else {
                    let stall = *m
                        .stall()
                        .unwrap_or_else(|| panic!("{tag}: all+halt must stall"));
                    assert!(stall.at >= kill_at, "{tag}: stalled before the kill");
                    assert_eq!(stall.alive, 2, "{tag}: the winner left the group");
                    assert_eq!(stall.required, 3, "{tag}");
                    assert!(
                        (hist.committed() as u64) < TXNS,
                        "{tag}: the halted run must abandon transactions"
                    );
                }
            }
        }
    }
}

/// A prior backup kill shapes the electorate: with slot 0 already dead
/// when the primary dies, the tie among the remaining converged peers
/// breaks to the lowest *surviving* id.
#[test]
fn backup_loss_shapes_the_electorate() {
    const TXNS: u64 = 8;
    let span = baseline_span(AckPolicy::Quorum(2), 1, TXNS);
    let plan = format!("kill:0@{},kill:p@{}", span / 8, span / 2);
    let mut m = build(AckPolicy::Quorum(2), faults(&plan, OnLoss::Degrade), 1);
    let mut t = ThreadCtx::new(0);
    let hist = drive_txns(&mut m, &mut t, TXNS);
    m.settle(t.now());
    assert!(m.stall().is_none(), "degrade rides through both losses");
    assert_eq!(hist.committed(), TXNS as usize);
    assert_eq!(m.membership_epochs(), 1);
    assert_eq!(
        m.fabric().primary_slot(),
        Some(1),
        "slot 0 is dead, so the tie breaks to slot 1"
    );
    let tl = m.fabric().timeline();
    assert_eq!(tl.epochs().len(), 1);
    assert_eq!(tl.primary_at(u64::MAX), Some(1));
}

/// The deposed primary rejoins as a backup: it takes over the winner's
/// vacated slot (seeded with the group state certified at the failover
/// instant) and the PR 2 catch-up resync streams everything since; the
/// serving primary then holds no backup slot at all.
#[test]
fn old_primary_rejoins_as_backup() {
    const TXNS: u64 = 10;
    let span = baseline_span(AckPolicy::Quorum(2), 1, TXNS);
    let kill_at = span / 4;
    let rejoin_at = span / 2;
    let plan = format!("kill:p@{kill_at},rejoin:p@{rejoin_at}");
    let mut m = build(AckPolicy::Quorum(2), faults(&plan, OnLoss::Halt), 1);
    let mut t = ThreadCtx::new(0);
    let hist = drive_txns(&mut m, &mut t, TXNS);
    assert!(m.stall().is_none());
    assert_eq!(hist.committed(), TXNS as usize);
    // Settle beyond any pending resync completion so the rejoiner is in.
    m.settle(t.now().max(rejoin_at + 10_000_000));
    assert_eq!(m.membership_epochs(), 1, "a rejoin is not a leadership change");
    assert_eq!(
        m.fabric().primary_slot(),
        None,
        "the serving primary holds no backup slot after the rejoin"
    );
    assert_eq!(m.fabric().state(0), BackupState::Alive, "slot 0 re-entered");
    let stats = m.fabric().backup_stats();
    assert_eq!(stats[0].resyncs, 1, "the rejoiner resynced through PR 2");
    // The slot's ledger froze while its machine served as primary; the
    // rejoiner's catch-up closes the gap with its peers.
    let ledgers = m.fabric().ledgers();
    assert_eq!(ledgers[0].len(), ledgers[1].len(), "resync must close the gap");
    let checked = recovery::check_leader_completeness(
        &ledgers,
        &hist,
        &[log_base_for(0)],
        &[D0, D1],
        &m.fabric().timeline(),
    )
    .expect("leader completeness across the round trip");
    assert_eq!(checked, 1);
}

/// SM-RC cannot host a demoted primary's catch-up resync (its
/// replicated-but-undrained lines are volatile), so `rejoin:p` is a
/// checked build error — while a kill-only primary plan builds fine.
#[test]
fn sm_rc_rejects_primary_rejoin() {
    let err = Mirror::try_build_faulted(
        Platform::default(),
        StrategyKind::SmRc,
        None,
        ReplicationConfig::new(3, AckPolicy::Quorum(2)),
        faults("kill:p@1000,rejoin:p@2000", OnLoss::Halt),
        true,
    )
    .expect_err("sm-rc must reject rejoin:p");
    assert!(err.to_string().contains("sm-rc"), "unexpected error: {err}");
    Mirror::try_build_faulted(
        Platform::default(),
        StrategyKind::SmRc,
        None,
        ReplicationConfig::new(3, AckPolicy::Quorum(2)),
        faults("kill:p@1000", OnLoss::Degrade),
        true,
    )
    .expect("a kill-only primary plan is fine under sm-rc");
}

/// The anchor: with no primary fault due, the membership machinery —
/// the per-op polls and the admission clamp — is a guard-clause
/// pass-through. An armed-but-never-due `kill:p` run is event-for-event
/// identical to the fault-free path, and a backup-only plan keeps every
/// membership counter at zero and the epoch log empty.
#[test]
fn no_primary_faults_is_a_guard_clause_pass_through() {
    let plat = Platform::default();
    let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
    let c = TransactConfig {
        epochs: 4,
        writes: 2,
        txns: 40,
        ..Default::default()
    };
    let mut plain =
        Mirror::try_build(plat.clone(), StrategyKind::SmOb, None, repl, true).unwrap();
    let base = run_transact_on(&mut plain, c);
    // The kill instant is far past the run's end, so the machinery is
    // armed on every op but never fires.
    let mut armed = Mirror::try_build_faulted(
        plat.clone(),
        StrategyKind::SmOb,
        None,
        repl,
        faults(&format!("kill:p@{}", 1u64 << 40), OnLoss::Halt),
        true,
    )
    .unwrap();
    let out = run_transact_on(&mut armed, c);
    assert_eq!(out.makespan, base.makespan, "makespan diverged");
    assert_eq!(out.txns, base.txns);
    assert_eq!(out.per_backup_horizon, base.per_backup_horizon);
    for b in 0..3 {
        assert_eq!(
            plain.backup(b).ledger.events(),
            armed.backup(b).ledger.events(),
            "backup {b} event stream diverged"
        );
    }
    assert_eq!(out.membership_epochs, 0);
    assert_eq!(out.failover_downtime_ns, 0);
    assert_eq!(out.rereplicated_lines, 0);
    assert_eq!(out.revoked_wqes, 0);
    assert!(armed.fabric().timeline().epochs().is_empty());
    assert_eq!(armed.fabric().primary_slot(), None);

    // Backup-only plan: the membership-epoch dimension stays degenerate.
    let span = baseline_span(AckPolicy::Quorum(2), 1, 8);
    let plan = format!("kill:1@{},rejoin:1@{}", span / 4, span / 2);
    let mut m = build(AckPolicy::Quorum(2), faults(&plan, OnLoss::Halt), 1);
    let mut t = ThreadCtx::new(0);
    let hist = drive_txns(&mut m, &mut t, 8);
    assert!(m.stall().is_none());
    assert_eq!(hist.committed(), 8);
    m.settle(t.now());
    assert_eq!(m.membership_epochs(), 0);
    assert_eq!(m.failover_downtime_ns(), 0);
    assert_eq!(m.revoked_wqes(), 0);
    assert!(m.fabric().timeline().epochs().is_empty());
}
