//! Lossy-link suite (`net::link`): the reliable-wire regression anchor
//! — an explicitly configured empty `[link]` section is event-for-event
//! identical (instants included) to the default construction path,
//! across replica groups, sharded construction and a faulted plan —
//! plus the transport behaviors the RC machinery must exhibit (timeout
//! retransmission, duplicate suppression at the ledger, RNR
//! backpressure, retry exhaustion healing as a transient-backup
//! episode), the adaptive-quorum × Degrade composition guard, and the
//! chaos property: under randomized seeded link faults, every strategy
//! × persist domain still commits every transaction, every backup's
//! final ledger image matches the lossless run's, and the merged crash
//! sweep covers every durably-acked transaction.

use std::collections::BTreeSet;

use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{Mirror, MirrorBuilder, ShardingConfig, ThreadCtx};
use pmsm::net::{Fabric, FaultsConfig, LinkConfig, OnLoss, PersistDomain, WriteMeta};
use pmsm::ptest::{check, Gen};
use pmsm::recovery;
use pmsm::runtime::fallback_predictor;
use pmsm::sim::ThreadClock;
use pmsm::txn::Txn;

/// Drive a deterministic single-thread Transact-shaped workload;
/// returns the thread's final virtual time.
fn drive(m: &mut Mirror, shape: &[(u32, u32)]) -> u64 {
    let mut t = ThreadCtx::new(0);
    for (i, &(epochs, writes)) in shape.iter().enumerate() {
        m.txn_begin(&mut t, None);
        for e in 0..epochs {
            for w in 0..writes {
                let addr =
                    0x1000_0000 + ((i as u64 * 7 + e as u64 * 3 + w as u64) % 32) * 64;
                m.store(&mut t, addr, i as u64);
                m.clwb(&mut t, addr);
            }
            m.sfence(&mut t);
        }
        m.txn_commit(&mut t);
    }
    t.now()
}

/// Per-backup ledger with every coordinate INCLUDING the durability
/// instant — the full event-for-event projection.
fn full_events(m: &Mirror, backup: usize) -> Vec<(u32, u64, u64, u64, u32, u64)> {
    m.backup(backup)
        .ledger
        .events()
        .iter()
        .map(|e| (e.thread, e.seq, e.addr, e.val, e.epoch, e.at))
        .collect()
}

/// The instant-free ledger image: what was replicated, not when.
fn image_keys(m: &Mirror, backup: usize) -> BTreeSet<(u32, u64, u64, u64)> {
    m.backup(backup)
        .ledger
        .events()
        .iter()
        .map(|e| (e.thread, e.seq, e.addr, e.val))
        .collect()
}

/// Every (thread, seq) pair appears exactly once — retransmits and
/// wire duplicates never double-apply at the ledger.
fn assert_psn_unique(m: &Mirror, backup: usize, label: &str) {
    let events = m.backup(backup).ledger.events().to_vec();
    let keys: BTreeSet<(u32, u64)> = events.iter().map(|e| (e.thread, e.seq)).collect();
    assert_eq!(
        keys.len(),
        events.len(),
        "{label} backup {backup}: duplicate (thread, seq) in the ledger"
    );
}

// ---------------------------------------------------------------------------
// The acceptance anchor: no `[link]` section == an explicitly empty one,
// bit for bit.

/// Building with an explicit default `LinkConfig` (empty plan, unbounded
/// receiver) is a guard-clause pass-through: same thread timeline, same
/// ledger (instants included), same doorbell/posted/wire counts as the
/// pre-link default path, for every SM strategy on a single backup.
#[test]
fn default_link_is_event_identical_to_the_prelink_path() {
    let shape = [(3u32, 2u32), (1, 4), (5, 1)];
    for kind in StrategyKind::SM {
        let mut legacy = MirrorBuilder::new(Platform::default(), kind)
            .replication(ReplicationConfig::new(1, AckPolicy::All))
            .ledger(true)
            .build()
            .unwrap();
        let legacy_now = drive(&mut legacy, &shape);
        let mut pinned = MirrorBuilder::new(Platform::default(), kind)
            .replication(ReplicationConfig::new(1, AckPolicy::All))
            .link(LinkConfig::default())
            .ledger(true)
            .build()
            .unwrap();
        let pinned_now = drive(&mut pinned, &shape);
        assert_eq!(legacy_now, pinned_now, "{kind:?}: thread timeline diverged");
        assert_eq!(
            full_events(&legacy, 0),
            full_events(&pinned, 0),
            "{kind:?}: ledger diverged under the explicit empty link"
        );
        assert_eq!(legacy.doorbells(), pinned.doorbells(), "{kind:?}");
        assert_eq!(legacy.posted_wqes(), pinned.posted_wqes(), "{kind:?}");
        assert_eq!(legacy.wire_wqes(), pinned.wire_wqes(), "{kind:?}");
        // The anchor never touches the transport machinery.
        assert_eq!(pinned.retransmits(), 0, "{kind:?}: anchor retransmitted");
        assert_eq!(pinned.transport_timeouts(), 0, "{kind:?}");
        assert_eq!(pinned.dup_drops(), 0, "{kind:?}: anchor ran dedup");
    }
}

/// The same pin through the sharded constructor and under a node-fault
/// plan: explicit empty link == default, instants included.
#[test]
fn empty_link_pins_sharded_and_faulted_paths() {
    // Sharded: 2 shards x 2 backups.
    let shape = [(2u32, 3u32), (4, 1)];
    let repl = ReplicationConfig::new(2, AckPolicy::All);
    let mut legacy = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(repl)
        .sharding(ShardingConfig::new(2, Default::default()))
        .ledger(true)
        .build()
        .unwrap();
    let legacy_now = drive(&mut legacy, &shape);
    let mut pinned = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(repl)
        .sharding(ShardingConfig::new(2, Default::default()))
        .link(LinkConfig::default())
        .ledger(true)
        .build()
        .unwrap();
    let pinned_now = drive(&mut pinned, &shape);
    assert_eq!(legacy_now, pinned_now, "sharded: thread timeline diverged");
    for s in 0..2 {
        for b in 0..2 {
            let ev = |m: &Mirror| -> Vec<(u32, u64, u64, u64, u32, u64)> {
                m.shard_fabric(s)
                    .backup(b)
                    .ledger
                    .events()
                    .iter()
                    .map(|e| (e.thread, e.seq, e.addr, e.val, e.epoch, e.at))
                    .collect()
            };
            assert_eq!(
                ev(&legacy),
                ev(&pinned),
                "shard {s} backup {b}: ledger diverged"
            );
        }
    }
    assert_eq!(legacy.doorbells(), pinned.doorbells());

    // Faulted: one kill mid-run on a quorum group.
    let shape = [(3u32, 2u32), (3, 2), (3, 2), (3, 2)];
    let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
    let faults = FaultsConfig::with_plan("kill:1@40000", OnLoss::Degrade).unwrap();
    let mut legacy = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(repl)
        .faults(faults.clone())
        .ledger(true)
        .build()
        .unwrap();
    let legacy_now = drive(&mut legacy, &shape);
    let mut pinned = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(repl)
        .faults(faults)
        .link(LinkConfig::default())
        .ledger(true)
        .build()
        .unwrap();
    let pinned_now = drive(&mut pinned, &shape);
    assert_eq!(legacy_now, pinned_now, "faulted: thread timeline diverged");
    for b in 0..3 {
        assert_eq!(
            full_events(&legacy, b),
            full_events(&pinned, b),
            "faulted backup {b}: ledger diverged"
        );
    }
    assert_eq!(legacy.doorbells(), pinned.doorbells());
}

// ---------------------------------------------------------------------------
// Transport behaviors.

/// A one-shot drop is masked by the ACK timeout + retransmit: the run
/// completes, the ledger image is unchanged (only instants shift, never
/// earlier), and the counters record exactly one timeout.
#[test]
fn lost_message_is_masked_by_retransmission() {
    let shape = [(3u32, 2u32), (2, 2)];
    let mut clean = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(ReplicationConfig::new(1, AckPolicy::All))
        .ledger(true)
        .build()
        .unwrap();
    let clean_now = drive(&mut clean, &shape);
    let mut lossy = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(ReplicationConfig::new(1, AckPolicy::All))
        .link(LinkConfig::with_plan("drop:0@0").unwrap())
        .ledger(true)
        .build()
        .unwrap();
    let lossy_now = drive(&mut lossy, &shape);
    assert_eq!(lossy.retransmits(), 1);
    assert_eq!(lossy.transport_timeouts(), 1);
    assert_eq!(lossy.qp_resets(), 0);
    assert!(lossy.backoff_ns() > 0);
    assert!(lossy_now >= clean_now, "a lost message cannot speed the run up");
    assert_eq!(
        image_keys(&clean, 0),
        image_keys(&lossy, 0),
        "the drop must not change WHAT was replicated"
    );
    assert_psn_unique(&lossy, 0, "one-shot drop");
    // Instants only ever move later under loss.
    let clean_at: std::collections::BTreeMap<(u32, u64), u64> = clean
        .backup(0)
        .ledger
        .events()
        .iter()
        .map(|e| ((e.thread, e.seq), e.at))
        .collect();
    for e in lossy.backup(0).ledger.events() {
        assert!(
            e.at >= clean_at[&(e.thread, e.seq)],
            "({}, {}): lossy persisted earlier than lossless",
            e.thread,
            e.seq
        );
    }
}

/// Wire duplicates — fabric-level dup events and the spurious
/// retransmit a long-delayed ack triggers — are dropped by the PSN
/// dedup at the ledger boundary: applied writes and the ledger stay
/// exactly-once.
#[test]
fn duplicates_are_suppressed_at_the_ledger() {
    let shape = [(3u32, 2u32), (2, 3)];
    let mut clean = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(ReplicationConfig::new(1, AckPolicy::All))
        .ledger(true)
        .build()
        .unwrap();
    drive(&mut clean, &shape);
    // dup:0@0 duplicates the first message; delay:0@2000:20000 delays
    // a later one past the 8 us ACK timeout, forcing a spurious
    // retransmit.
    let mut lossy = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(ReplicationConfig::new(1, AckPolicy::All))
        .link(LinkConfig::with_plan("dup:0@0,delay:0@2000:20000").unwrap())
        .ledger(true)
        .build()
        .unwrap();
    drive(&mut lossy, &shape);
    assert!(lossy.dups_injected() >= 2, "both events must inject a duplicate");
    assert_eq!(
        lossy.dup_drops(),
        lossy.dups_injected(),
        "every duplicate delivery must be dropped by dedup"
    );
    assert!(lossy.dup_drops() <= lossy.retransmits() + lossy.dups_injected());
    assert_eq!(image_keys(&clean, 0), image_keys(&lossy, 0));
    assert_psn_unique(&lossy, 0, "duplicates");
    // The applied-write counter excludes the dropped copies.
    assert_eq!(
        lossy.fabric().backup_stats()[0].writes,
        clean.fabric().backup_stats()[0].writes,
        "dedup must keep the applied-write count exactly-once"
    );
}

/// RNR backpressure: a depth-1 receiver NAKs bursts; NAK retries count
/// as retransmits but never as ACK timeouts, and nothing is lost.
#[test]
fn rnr_nak_backpressure_is_lossless() {
    let shape = [(2u32, 4u32), (2, 4)];
    let mut clean = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(ReplicationConfig::new(1, AckPolicy::All))
        .ledger(true)
        .build()
        .unwrap();
    drive(&mut clean, &shape);
    let link = LinkConfig {
        rnr_depth: 1,
        ..LinkConfig::default()
    };
    let mut lossy = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(ReplicationConfig::new(1, AckPolicy::All))
        .link(link)
        .ledger(true)
        .build()
        .unwrap();
    drive(&mut lossy, &shape);
    assert!(lossy.rnr_naks() > 0, "a depth-1 receiver never NAKed");
    assert_eq!(lossy.transport_timeouts(), 0, "an RNR NAK is not an ACK timeout");
    assert!(lossy.retransmits() >= lossy.rnr_naks());
    assert_eq!(image_keys(&clean, 0), image_keys(&lossy, 0));
    assert_psn_unique(&lossy, 0, "rnr");
}

/// Retry exhaustion heals as a transient-backup episode: the QP resets,
/// the backup leaves the quorum (Degrade carries the run), rejoins via
/// the ordinary resync, and after settling its ledger image converges
/// back to the survivor's.
#[test]
fn qp_exhaustion_heals_as_a_transient_backup_episode() {
    let shape = [(3u32, 2u32); 6];
    // A 100% window opening early in the run (the backoff chain at
    // retry 2 spans 8 + 16 + 32 us, well inside the window), so the
    // first lost message deterministically exhausts its retries.
    let mut link = LinkConfig::with_plan("drop:1@5000..200000:100%").unwrap();
    link.retry_count = 2;
    let mut m = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(ReplicationConfig::new(2, AckPolicy::Quorum(1)))
        .faults(FaultsConfig::with_plan("", OnLoss::Degrade).unwrap())
        .link(link)
        .ledger(true)
        .build()
        .unwrap();
    let now = drive(&mut m, &shape);
    assert!(m.stall().is_none(), "quorum:1 + degrade must mask the lost link");
    assert!(m.qp_resets() >= 1, "the loss window never exhausted the QP");
    // The episode went through the node-fault machinery: the healed
    // backup accrued out-of-quorum time and resynced lines on rejoin.
    let far = now + 50_000_000;
    m.settle(far);
    m.settle(far + 50_000_000);
    assert!(
        m.accrued_dead_ns(far)[1] > 0,
        "the exhausted backup never left the quorum"
    );
    assert!(m.resync_lines()[1] > 0, "the rejoin never resynced");
    assert_eq!(
        image_keys(&m, 0),
        image_keys(&m, 1),
        "after healing + resync the ledger images must converge"
    );
    assert_psn_unique(&m, 0, "exhaustion");
    assert_psn_unique(&m, 1, "exhaustion");
}

/// `OnLoss::Halt` extends to links: retry exhaustion on a required
/// backup stalls the run instead of weakening durability.
#[test]
fn on_loss_halt_stalls_when_a_required_link_dies() {
    let shape = [(3u32, 2u32); 6];
    let mut link = LinkConfig::with_plan("drop:1@5000..600000:100%").unwrap();
    link.retry_count = 2;
    let mut m = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(ReplicationConfig::new(2, AckPolicy::All))
        .faults(FaultsConfig::with_plan("", OnLoss::Halt).unwrap())
        .link(link)
        .ledger(true)
        .build()
        .unwrap();
    drive(&mut m, &shape);
    let stall = m.stall().expect("ack all + halt must stall on a dead link");
    assert!(stall.at >= 5_000, "stalled before the loss window opened");
    assert!(m.qp_resets() >= 1);
}

// ---------------------------------------------------------------------------
// Adaptive-quorum x Degrade composition guard (regression).

/// A per-txn adaptive quorum override must never make a fence wait on a
/// dead backup: when a backup dies mid-txn, the override's floor clamps
/// to the survivor count, so the fence completes exactly like a static
/// quorum over the survivors — no stall, no phantom wait.
#[test]
fn txn_quorum_override_composes_with_degrade_clamping() {
    let p = Platform::default();
    let repl = ReplicationConfig::new(3, AckPolicy::Quorum(1));
    let faults = FaultsConfig::with_plan("kill:2@10000", OnLoss::Degrade).unwrap();
    let mut f = Fabric::with_faults(&p, &repl, faults.clone(), false);
    // The controller asks for all 3 acks on this txn's fences.
    f.set_txn_quorum(Some(3));
    // Reference: a static quorum:2 group under the same plan — after
    // the kill, 2 survivors is exactly what the clamped override waits
    // on.
    let static_repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
    let mut r = Fabric::with_faults(&p, &static_repl, faults, false);
    let mut tf = ThreadClock::new(0);
    let mut tr = ThreadClock::new(0);
    // Past the kill: backup 2 is dead; the override's k=3 must clamp to
    // the 2 survivors rather than waiting on the corpse (or stalling).
    tf.wait_until(20_000);
    tr.wait_until(20_000);
    for (i, t, fab) in [(0u64, &mut tf, &mut f), (0u64, &mut tr, &mut r)] {
        fab.post_write_wt(
            t,
            WriteMeta {
                addr: 0x40,
                val: i,
                thread: 0,
                txn: 0,
                epoch: 0,
                seq: i,
            },
        );
        fab.rdfence(t);
    }
    assert!(f.stall().is_none(), "the override must not stall a degraded group");
    assert!(r.stall().is_none());
    assert_eq!(
        tf.now, tr.now,
        "clamped override (k=3 -> 2 survivors) must fence exactly like \
         static quorum:2"
    );
    // The override survives as asked (it re-applies if the backup
    // rejoins) — only its effective value clamps per fence.
    assert_eq!(f.txn_quorum(), Some(3));
}

// ---------------------------------------------------------------------------
// Chaos property: strategies x persist domains under random link plans.

/// One randomized lossy run vs its lossless twin: same strategy, same
/// domain, same transactions. Checks commit completeness, ledger-image
/// equality, PSN uniqueness, and the merged fault-aware crash sweep.
fn chaos_case(g: &mut Gen, kind: StrategyKind, domain: PersistDomain) {
    let txns = g.u64(2, 5);
    let backups = 2;
    let repl = ReplicationConfig::new(backups, AckPolicy::Quorum(1));
    let build = |link: Option<LinkConfig>| -> Mirror {
        let mut b = MirrorBuilder::new(Platform::default(), kind)
            .replication(repl)
            .faults(FaultsConfig::with_plan("", OnLoss::Degrade).unwrap())
            .persist_domain(domain)
            .ledger(true);
        if kind == StrategyKind::SmAd {
            b = b.predictor(fallback_predictor(&Platform::default()));
        }
        if let Some(link) = link {
            b = b.link(link);
        }
        b.build().unwrap()
    };
    // A random plan: a run-long loss rate on a random backup (<= 30% so
    // the default retry budget keeps exhaustion rare), plus up to two
    // one-shot events, under a random seed.
    let mut spec = format!("loss:{}:{}%", g.usize(0, backups - 1), g.u64(0, 30));
    for _ in 0..g.usize(0, 2) {
        let b = g.usize(0, backups - 1);
        let at = g.u64(1_000, 80_000);
        match g.usize(0, 2) {
            0 => spec.push_str(&format!(",drop:{b}@{at}")),
            1 => spec.push_str(&format!(",dup:{b}@{at}")),
            _ => spec.push_str(&format!(",delay:{b}@{at}:{}", g.u64(100, 20_000))),
        }
    }
    let mut link = LinkConfig::with_plan(&spec).unwrap();
    link.seed = g.u64(0, u64::MAX / 2);
    // A generous retry budget keeps retry exhaustion (and its kill +
    // rejoin episode) out of the chaos property — the exhaustion path
    // has its own dedicated tests above; here every loss must be
    // masked purely by retransmission so the ledger images stay
    // instant-for-instant comparable as key sets.
    link.retry_count = 16;

    let log = pmsm::pstore::log_base_for(0);
    let d0 = 0x20_0000u64;
    let d1 = 0x20_0040u64;
    let run = |m: &mut Mirror| -> (recovery::TxnHistory, u64) {
        let mut t = ThreadCtx::new(0);
        let mut hist = recovery::TxnHistory::new(Default::default());
        for i in 0..txns {
            let mut tx = Txn::begin(m, &mut t, log, None);
            tx.write(m, &mut t, d0, 100 + i);
            tx.write(m, &mut t, d1, 200 + i);
            tx.commit(m, &mut t);
            assert!(m.stall().is_none(), "degrade must never stall");
            let mut snap = std::collections::HashMap::new();
            snap.insert(d0, 100 + i);
            snap.insert(d1, 200 + i);
            hist.commit(snap, t.last_dfence);
        }
        // Settle twice with a wide horizon: the second pass lands any
        // rejoin a QP heal scheduled after the first.
        let far = t.now() + 50_000_000;
        m.settle(far);
        m.settle(far + 50_000_000);
        (hist, t.now())
    };
    let mut clean = build(None);
    let (_, _) = run(&mut clean);
    let mut lossy = build(Some(link));
    let (hist, _) = run(&mut lossy);
    let label = format!("{kind:?}/{domain}/{spec}");

    // Ledger truth: what was replicated matches the lossless run
    // exactly, on every backup, exactly once.
    for b in 0..backups {
        assert_eq!(
            image_keys(&clean, b),
            image_keys(&lossy, b),
            "{label} backup {b}: lossy ledger image diverged"
        );
        assert_psn_unique(&lossy, b, &label);
    }
    assert!(
        lossy.dup_drops() <= lossy.retransmits() + lossy.dups_injected(),
        "{label}: dedup dropped more than was ever duplicated"
    );
    assert!(
        lossy.retransmits() >= lossy.transport_timeouts(),
        "{label}: timeouts without retransmits"
    );

    // Recovery: the merged fault-aware crash sweep covers every durably
    // acked transaction despite loss, retransmission and healing.
    let shard_ledgers = lossy.shard_ledgers();
    for ledgers in &shard_ledgers {
        recovery::check_group_epoch_ordering(ledgers).unwrap();
    }
    let timeline = lossy.fabric().timeline();
    let log_bases = [log];
    let data_addrs = [d0, d1];
    let check = recovery::CrashCheck::new(&hist, &log_bases, &data_addrs)
        .required(repl.required())
        .on_loss(OnLoss::Degrade)
        .persist_domain(domain);
    let checked = check
        .ledgers(&shard_ledgers[0])
        .faults(&timeline)
        .sweep()
        .unwrap_or_else(|e| panic!("{label}: crash sweep failed: {e}"));
    assert!(checked > 0, "{label}: the sweep checked nothing");
}

/// The chaos matrix: every mirroring strategy x every persist domain,
/// each under a handful of random seeded link plans.
#[test]
fn prop_chaos_lossy_runs_preserve_ledger_truth_and_recovery() {
    for kind in [
        StrategyKind::SmRc,
        StrategyKind::SmOb,
        StrategyKind::SmDd,
        StrategyKind::SmAd,
    ] {
        for domain in PersistDomain::ALL {
            check(
                &format!("lossy-chaos-{kind}-{domain}"),
                3,
                |g: &mut Gen| chaos_case(g, kind, domain),
            );
        }
    }
}

/// The fifth strategy: without mirroring there is no wire, so a link
/// config is inert — NO-SM runs untouched under any plan.
#[test]
fn no_sm_is_untouched_by_link_plans() {
    let shape = [(3u32, 2u32), (2, 4)];
    let mut plain = Mirror::new(Platform::default(), StrategyKind::NoSm, false);
    let plain_now = drive(&mut plain, &shape);
    let mut linked = MirrorBuilder::new(Platform::default(), StrategyKind::NoSm)
        .link(LinkConfig::with_plan("drop:0@1000,loss:0:50%").unwrap())
        .build()
        .unwrap();
    let linked_now = drive(&mut linked, &shape);
    assert_eq!(plain_now, linked_now, "NO-SM must not see the link layer");
    assert_eq!(linked.retransmits(), 0);
    assert_eq!(linked.transport_timeouts(), 0);
}
