//! Persist-domain suite (`net::remote::PersistDomain`): the ADR
//! regression anchor — an explicitly configured `adr` domain is
//! event-for-event identical (instants included) to the default
//! construction path, across replica groups, sharded construction and
//! a faulted plan — plus the verdict-nesting property: at every crash
//! instant the durable event set under eADR contains ADR's, which
//! contains RpmemFlush's (completion-implies-persistent widens
//! verdicts; explicit-flush narrows them).

use std::collections::{BTreeMap, BTreeSet};

use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{Mirror, MirrorBuilder, ShardingConfig, ThreadCtx};
use pmsm::net::{FaultsConfig, OnLoss, PersistDomain};
use pmsm::ptest::{check, Gen};

/// Drive a deterministic single-thread Transact-shaped workload;
/// returns the thread's final virtual time.
fn drive(m: &mut Mirror, shape: &[(u32, u32)]) -> u64 {
    let mut t = ThreadCtx::new(0);
    for (i, &(epochs, writes)) in shape.iter().enumerate() {
        m.txn_begin(&mut t, None);
        for e in 0..epochs {
            for w in 0..writes {
                let addr =
                    0x1000_0000 + ((i as u64 * 7 + e as u64 * 3 + w as u64) % 32) * 64;
                m.store(&mut t, addr, i as u64);
                m.clwb(&mut t, addr);
            }
            m.sfence(&mut t);
        }
        m.txn_commit(&mut t);
    }
    t.now()
}

/// Per-backup ledger with every coordinate INCLUDING the durability
/// instant — the full event-for-event projection.
fn full_events(m: &Mirror, backup: usize) -> Vec<(u32, u64, u64, u64, u32, u64)> {
    m.backup(backup)
        .ledger
        .events()
        .iter()
        .map(|e| (e.thread, e.seq, e.addr, e.val, e.epoch, e.at))
        .collect()
}

/// The acceptance anchor: `--persist-domain adr` is a guard-clause
/// pass-through — building with the explicit domain produces the same
/// thread timeline, the same ledger (instants included) and the same
/// doorbell count as the pre-domain default path, for every SM
/// strategy on a single backup.
#[test]
fn explicit_adr_is_event_identical_to_the_default_path() {
    let shape = [(3u32, 2u32), (1, 4), (5, 1)];
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        let mut legacy = Mirror::with_replication(
            Platform::default(),
            kind,
            ReplicationConfig::new(1, AckPolicy::All),
            true,
        )
        .unwrap();
        let legacy_now = drive(&mut legacy, &shape);
        let mut pinned = MirrorBuilder::new(Platform::default(), kind)
            .replication(ReplicationConfig::new(1, AckPolicy::All))
            .persist_domain(PersistDomain::Adr)
            .ledger(true)
            .build()
            .unwrap();
        let pinned_now = drive(&mut pinned, &shape);
        assert_eq!(legacy_now, pinned_now, "{kind:?}: thread timeline diverged");
        assert_eq!(
            full_events(&legacy, 0),
            full_events(&pinned, 0),
            "{kind:?}: ledger diverged under the explicit adr domain"
        );
        assert_eq!(legacy.doorbells(), pinned.doorbells(), "{kind:?}");
        assert_eq!(legacy.posted_wqes(), pinned.posted_wqes(), "{kind:?}");
        // The anchor domain never emits the new-domain artifacts.
        assert_eq!(pinned.flush_verbs(), 0, "{kind:?}: adr issued flush verbs");
        assert_eq!(pinned.compaction_lines(), 0, "{kind:?}: adr compacted");
    }
}

/// The same pin through the sharded constructor (shards = 1, the
/// default map): explicit adr == default, instants included.
#[test]
fn explicit_adr_pins_the_sharded_construction_path() {
    let shape = [(2u32, 3u32), (4, 1)];
    let repl = ReplicationConfig::new(2, AckPolicy::All);
    let mut legacy = Mirror::try_build_sharded(
        Platform::default(),
        StrategyKind::SmOb,
        None,
        repl,
        FaultsConfig::default(),
        ShardingConfig::default(),
        true,
    )
    .unwrap();
    let legacy_now = drive(&mut legacy, &shape);
    let mut pinned = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(repl)
        .sharding(ShardingConfig::default())
        .persist_domain(PersistDomain::Adr)
        .ledger(true)
        .build()
        .unwrap();
    let pinned_now = drive(&mut pinned, &shape);
    assert_eq!(legacy_now, pinned_now, "thread timeline diverged");
    for b in 0..2 {
        assert_eq!(
            full_events(&legacy, b),
            full_events(&pinned, b),
            "backup {b}: ledger diverged"
        );
    }
    assert_eq!(legacy.doorbells(), pinned.doorbells());
}

/// The pin under failure dynamics: one kill mid-run on a quorum group
/// behaves identically with the domain spelled out — survivors' and the
/// dead backup's ledgers match event-for-event, instants included.
#[test]
fn explicit_adr_pins_a_faulted_plan() {
    let shape = [(3u32, 2u32), (3, 2), (3, 2), (3, 2)];
    let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
    let faults = FaultsConfig::with_plan("kill:1@40000", OnLoss::Degrade).unwrap();
    let mut legacy = Mirror::try_build_sharded(
        Platform::default(),
        StrategyKind::SmOb,
        None,
        repl,
        faults.clone(),
        ShardingConfig::default(),
        true,
    )
    .unwrap();
    let legacy_now = drive(&mut legacy, &shape);
    let mut pinned = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
        .replication(repl)
        .faults(faults)
        .persist_domain(PersistDomain::Adr)
        .ledger(true)
        .build()
        .unwrap();
    let pinned_now = drive(&mut pinned, &shape);
    assert_eq!(legacy_now, pinned_now, "thread timeline diverged");
    for b in 0..3 {
        assert_eq!(
            full_events(&legacy, b),
            full_events(&pinned, b),
            "backup {b}: ledger diverged under the faulted plan"
        );
    }
    assert_eq!(legacy.doorbells(), pinned.doorbells());
}

/// Verdict nesting: run the same workload under each domain and compare
/// the durable event set at crash instants. Per replicated event the
/// persist instants order eADR <= ADR <= RpmemFlush, so at EVERY crash
/// point the verdict sets nest eADR >= ADR >= RpmemFlush; random crash
/// points spot-check the set statement itself.
#[test]
fn prop_verdict_sets_nest_eadr_adr_rpmem() {
    check("persist-domain-verdict-nesting", 15, |g: &mut Gen| {
        let kind = *g.pick(&[StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd]);
        let backups = g.usize(1, 2);
        let txns = g.u64(1, 4);
        let shape: Vec<(u32, u32)> = (0..txns)
            .map(|_| (g.u64(1, 5) as u32, g.u64(1, 6) as u32))
            .collect();
        let run = |domain: PersistDomain| -> Mirror {
            let mut m = MirrorBuilder::new(Platform::default(), kind)
                .replication(ReplicationConfig::new(backups, AckPolicy::All))
                .persist_domain(domain)
                .ledger(true)
                .build()
                .unwrap();
            drive(&mut m, &shape);
            m
        };
        let eadr = run(PersistDomain::Eadr);
        let adr = run(PersistDomain::Adr);
        let rpmem = run(PersistDomain::RpmemFlush);
        for b in 0..backups {
            let key_at = |m: &Mirror| -> BTreeMap<(u32, u64), u64> {
                m.backup(b)
                    .ledger
                    .events()
                    .iter()
                    .map(|e| ((e.thread, e.seq), e.at))
                    .collect()
            };
            let (we, wa, wr) = (key_at(&eadr), key_at(&adr), key_at(&rpmem));
            // Every domain replicates the same committed event set —
            // only the persist instants move.
            let keys: BTreeSet<_> = wa.keys().copied().collect();
            assert_eq!(keys, we.keys().copied().collect(), "{kind:?} backup {b}");
            assert_eq!(keys, wr.keys().copied().collect(), "{kind:?} backup {b}");
            // Instant ordering — the strong form that implies nesting at
            // every conceivable crash point.
            for (k, &at_adr) in &wa {
                assert!(
                    we[k] <= at_adr,
                    "{kind:?} backup {b} {k:?}: eadr persisted later ({} > {at_adr})",
                    we[k]
                );
                assert!(
                    at_adr <= wr[k],
                    "{kind:?} backup {b} {k:?}: rpmem persisted earlier ({} < {at_adr})",
                    wr[k]
                );
            }
            // And the verdict-set statement at random crash instants.
            let horizon = wr.values().max().copied().unwrap_or(0) + 1_000;
            for _ in 0..8 {
                let crash = g.u64(0, horizon);
                let durable = |w: &BTreeMap<(u32, u64), u64>| -> BTreeSet<(u32, u64)> {
                    w.iter().filter(|&(_, &at)| at <= crash).map(|(&k, _)| k).collect()
                };
                let (se, sa, sr) = (durable(&we), durable(&wa), durable(&wr));
                assert!(
                    sr.is_subset(&sa),
                    "{kind:?} backup {b} crash {crash}: rpmem verdicts escape adr's"
                );
                assert!(
                    sa.is_subset(&se),
                    "{kind:?} backup {b} crash {crash}: adr verdicts escape eadr's"
                );
            }
        }
    });
}
