//! Integration: the AOT artifacts (built by `make artifacts`) load and
//! execute through the rust PJRT runtime, and agree with the in-repo
//! implementations — the A3 cross-validation layer.
//!
//! These tests are skipped (with a loud message) when `artifacts/` is
//! missing, so `cargo test` works before `make artifacts`; `make test`
//! always builds artifacts first.

use pmsm::config::Platform;
use pmsm::mem::SliceHash;
use pmsm::runtime::{fallback_predictor, CacheIndexModel, LatencyModel};
use pmsm::util::Pcg64;

fn artifacts_present() -> bool {
    let dir = pmsm::runtime::artifacts_dir();
    let ok = std::path::Path::new(&format!("{dir}/latency_model.hlo.txt")).exists()
        && std::path::Path::new(&format!("{dir}/cache_index.hlo.txt")).exists();
    if !ok {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
    }
    ok
}

#[test]
fn latency_model_loads_and_predicts() {
    if !artifacts_present() {
        return;
    }
    let plat = Platform::default();
    let model = LatencyModel::load(&plat).expect("load latency model");
    let e = [1.0f32, 4.0, 16.0, 64.0, 256.0];
    let w = [1.0f32; 5];
    let (lat, slow) = model.predict(&e, &w).expect("predict");
    assert_eq!(lat.len(), 5);
    assert_eq!(slow.len(), 5);
    for (i, l) in lat.iter().enumerate() {
        assert!(l[0] > 0.0, "cfg {i}: NO-SM latency must be positive");
        // Every SM strategy costs at least NO-SM.
        for s in 1..4 {
            assert!(l[s] >= l[0], "cfg {i} strategy {s}: {l:?}");
        }
        // RC is never the best SM strategy (paper headline).
        assert!(l[1] >= l[2].min(l[3]), "cfg {i}: {l:?}");
    }
}

#[test]
fn latency_model_matches_closed_form_fallback() {
    if !artifacts_present() {
        return;
    }
    let plat = Platform::default();
    let model = LatencyModel::load(&plat).expect("load");
    let fallback = fallback_predictor(&plat);
    let e = [1.0f32, 2.0, 8.0, 32.0, 128.0, 256.0];
    let w = [1.0f32, 2.0, 4.0, 8.0, 1.0, 2.0];
    let (lat, _) = model.predict(&e, &w).expect("predict");
    for i in 0..e.len() {
        let (ob, dd) = fallback(e[i], w[i]);
        let rel = |a: f32, b: f32| (a - b).abs() / b.max(1.0);
        assert!(
            rel(lat[i][2], ob) < 1e-4,
            "OB mismatch at {}-{}: pjrt {} vs fallback {}",
            e[i],
            w[i],
            lat[i][2],
            ob
        );
        assert!(
            rel(lat[i][3], dd) < 1e-4,
            "DD mismatch at {}-{}: pjrt {} vs fallback {}",
            e[i],
            w[i],
            lat[i][3],
            dd
        );
    }
}

#[test]
fn predictor_reproduces_crossover() {
    if !artifacts_present() {
        return;
    }
    let plat = Platform::default();
    let model = LatencyModel::load(&plat).expect("load");
    let predictor = model.predictor().expect("predictor");
    let (ob_small, dd_small) = predictor(4.0, 1.0);
    assert!(dd_small < ob_small, "DD should win 4-1");
    let (ob_big, dd_big) = predictor(256.0, 1.0);
    assert!(ob_big < dd_big, "OB should win 256-1");
}

#[test]
fn cache_index_kernel_matches_rust_hash() {
    if !artifacts_present() {
        return;
    }
    let plat = Platform::default();
    let model = CacheIndexModel::load(&plat).expect("load cache index");
    let hash = SliceHash::from(&plat);
    let mut rng = Pcg64::new(0xCAFE);
    let addrs: Vec<u64> = (0..1024).map(|_| rng.next_u64() & ((1 << 40) - 1)).collect();
    let got = model.cache_sets(&addrs).expect("cache_sets");
    for (i, (&addr, &set)) in addrs.iter().zip(&got).enumerate() {
        assert_eq!(
            set as usize,
            hash.global_set(addr),
            "idx {i} addr {addr:#x}"
        );
    }
}

#[test]
fn cache_index_partial_batch() {
    if !artifacts_present() {
        return;
    }
    let plat = Platform::default();
    let model = CacheIndexModel::load(&plat).expect("load");
    let got = model.cache_sets(&[0, 64, 128]).expect("cache_sets");
    assert_eq!(got.len(), 3);
    let hash = SliceHash::from(&plat);
    assert_eq!(got[1] as usize, hash.global_set(64));
}
