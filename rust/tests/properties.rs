//! Property-based tests (mini-proptest harness, `pmsm::ptest`) over the
//! coordinator's core invariants: ordering, durability, recovery, and
//! model-component properties under randomized configurations.

use pmsm::config::{Platform, StrategyKind};
use pmsm::coordinator::{Mirror, ThreadCtx};
use pmsm::mem::MemCtrl;
use pmsm::ptest::{check, Gen};
use pmsm::pstore::log_base_for;
use pmsm::recovery::{self, TxnHistory};
use pmsm::sim::RateLimiter;
use pmsm::txn::Txn;
use std::collections::HashMap;

fn strategy_of(g: &mut Gen) -> StrategyKind {
    *g.pick(&[StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd])
}

#[test]
fn prop_epoch_ordering_random_transactions() {
    check("epoch-ordering", 40, |g| {
        let kind = strategy_of(g);
        let txns = g.u64(1, 6);
        let epochs = g.u64(1, 8) as u32;
        let writes = g.u64(1, 4) as u32;
        let mut m = Mirror::new(Platform::default(), kind, true);
        let mut t = ThreadCtx::new(0);
        for i in 0..txns {
            m.txn_begin(&mut t, None);
            for e in 0..epochs {
                for w in 0..writes {
                    let addr = 0x1000_0000 + ((i + e as u64 * 3 + w as u64) % 16) * 64;
                    m.store(&mut t, addr, i);
                    m.clwb(&mut t, addr);
                }
                m.sfence(&mut t);
            }
            m.txn_commit(&mut t);
        }
        recovery::check_epoch_ordering(&m.backup(0).ledger).unwrap();
    });
}

#[test]
fn prop_durability_fence_covers_everything() {
    check("durability-fence", 40, |g| {
        let kind = strategy_of(g);
        let epochs = g.u64(1, 16) as u32;
        let writes = g.u64(1, 4) as u32;
        let mut m = Mirror::new(Platform::default(), kind, true);
        let mut t = ThreadCtx::new(0);
        m.txn_begin(&mut t, None);
        for e in 0..epochs {
            for w in 0..writes {
                let addr = 0x2000_0000 + (e * writes + w) as u64 * 64;
                m.store(&mut t, addr, 7);
                m.clwb(&mut t, addr);
            }
            m.sfence(&mut t);
        }
        m.txn_commit(&mut t);
        // Every replicated write persisted no later than the dfence.
        let dfence = t.last_dfence;
        for ev in m.backup(0).ledger.events() {
            assert!(
                ev.at <= dfence,
                "write at {} after dfence {}",
                ev.at,
                dfence
            );
        }
        assert_eq!(
            m.backup(0).ledger.len() as u64,
            (epochs * writes) as u64
        );
    });
}

#[test]
fn prop_crash_consistency_random_workloads() {
    check("crash-consistency", 15, |g| {
        let kind = strategy_of(g);
        let txns = g.u64(1, 5);
        let wpt = g.u64(1, 3); // writes per txn
        let mut m = Mirror::new(Platform::default(), kind, true);
        let mut t = ThreadCtx::new(0);
        let log = log_base_for(0);
        let addrs: Vec<u64> = (0..4).map(|i| 0x3000_0000 + i * 64).collect();
        let mut hist = TxnHistory::new(HashMap::new());
        let mut img: HashMap<u64, u64> = HashMap::new();
        for i in 0..txns {
            let mut tx = Txn::begin(&mut m, &mut t, log, None);
            for k in 0..wpt {
                let a = addrs[((i + k) % 4) as usize];
                let v = i * 100 + k;
                tx.write(&mut m, &mut t, a, v);
                img.insert(a, v);
            }
            tx.commit(&mut m, &mut t);
            hist.commit(img.clone(), t.last_dfence);
        }
        recovery::check_all_crashes(&m.backup(0).ledger, &hist, &[log], &addrs)
            .unwrap();
    });
}

#[test]
fn prop_rate_limiter_conserves_capacity() {
    check("rate-limiter-capacity", 60, |g| {
        let occ = g.u64(10, 500);
        let n = g.u64(10, 300);
        let spread = g.u64(1, 100_000);
        let mut rl = RateLimiter::new(occ);
        let mut starts: Vec<u64> = Vec::new();
        for i in 0..n {
            // Arbitrary (possibly decreasing) arrival pattern.
            let at = (i * 7919 + 13) % spread;
            starts.push(rl.submit(at));
        }
        // Capacity conservation: within any window of W ns, at most
        // ~W/occ + slack requests may start.
        starts.sort_unstable();
        let w = occ * 32;
        for (i, &s) in starts.iter().enumerate() {
            let until = s + w;
            let in_window = starts[i..].iter().take_while(|&&x| x < until).count();
            let cap = (w / occ) as usize + 2 * 64 + 2; // window granularity slack
            assert!(
                in_window <= cap,
                "{in_window} starts within {w}ns window (occ={occ})"
            );
        }
    });
}

#[test]
fn prop_memctrl_admission_precedes_landing_and_is_monotonic_per_stream() {
    check("memctrl-admission", 60, |g| {
        let depth = g.usize(2, 128);
        let banks = g.usize(1, 8);
        let drain = g.u64(50, 400);
        let mut mc = MemCtrl::new(depth, banks, drain, 10);
        let n = g.u64(5, 200);
        let mut at = 0u64;
        let mut last_admit = 0u64;
        for _ in 0..n {
            at += g.u64(0, 300);
            let (admit, pm) = mc.push(at);
            assert!(admit >= at, "admission before arrival");
            assert!(pm > admit, "PM landing must follow admission");
            // Monotone for a monotone arrival stream.
            assert!(admit >= last_admit);
            last_admit = admit;
        }
    });
}

#[test]
fn prop_transact_slowdown_ordering_random_platforms() {
    check("strategy-ordering", 10, |g| {
        let mut p = Platform::default();
        p.rtt = g.u64(1_000, 5_000);
        p.gap = g.u64(50, 300);
        p.mc_pm = g.u64(80, 300);
        let cfg = pmsm::workloads::TransactConfig {
            epochs: g.u64(2, 32) as u32,
            writes: g.u64(1, 4) as u32,
            txns: 40,
            ..Default::default()
        };
        let base =
            pmsm::workloads::run_transact(&p, StrategyKind::NoSm, cfg).makespan as f64;
        let rc =
            pmsm::workloads::run_transact(&p, StrategyKind::SmRc, cfg).makespan as f64;
        let ob =
            pmsm::workloads::run_transact(&p, StrategyKind::SmOb, cfg).makespan as f64;
        let dd =
            pmsm::workloads::run_transact(&p, StrategyKind::SmDd, cfg).makespan as f64;
        // Under ANY platform: SM costs more than NO-SM, and RC (blocking
        // round trip per epoch) is never better than both OB and DD.
        assert!(rc >= base && ob >= base && dd >= base);
        assert!(rc >= ob.min(dd) * 0.999, "rc={rc} ob={ob} dd={dd}");
    });
}

#[test]
fn prop_fault_policy_completion_ordering() {
    // For random write streams and random kill plans (degrade mode, so
    // every run completes), group completion time is monotone in the ack
    // requirement: quorum:1 <= quorum:2 = majority-of-3 <= all.
    use pmsm::config::{AckPolicy, ReplicationConfig};
    use pmsm::net::{FaultsConfig, OnLoss};
    use pmsm::workloads::transact::run_transact_faulted;
    check("fault-policy-ordering", 8, |g| {
        let cfg = pmsm::workloads::TransactConfig {
            epochs: g.u64(2, 6) as u32,
            writes: g.u64(1, 3) as u32,
            txns: 25,
            ..Default::default()
        };
        let p = Platform::default();
        // Place a kill (and sometimes a rejoin) inside the fault-free span.
        let span = run_transact_faulted(
            &p,
            StrategyKind::SmOb,
            ReplicationConfig::new(3, AckPolicy::All),
            FaultsConfig::default(),
            cfg,
        )
        .unwrap()
        .makespan;
        let victim = g.usize(0, 2);
        let kill_at = g.u64(span / 10, span);
        let plan = if g.bool() {
            format!("kill:{victim}@{kill_at},rejoin:{victim}@{}", kill_at + span / 4)
        } else {
            format!("kill:{victim}@{kill_at}")
        };
        let mk = |policy| {
            let out = run_transact_faulted(
                &p,
                StrategyKind::SmOb,
                ReplicationConfig::new(3, policy),
                FaultsConfig::with_plan(&plan, OnLoss::Degrade).unwrap(),
                cfg,
            )
            .unwrap();
            assert!(out.stalled.is_none(), "degrade must complete ({plan})");
            assert_eq!(out.txns, cfg.txns);
            out.makespan
        };
        let q1 = mk(AckPolicy::Quorum(1)) as f64;
        let q2 = mk(AckPolicy::Quorum(2)) as f64;
        let maj = mk(AckPolicy::Majority) as f64;
        let all = mk(AckPolicy::All) as f64;
        // Tiny slack absorbs sub-RTT modeling noise, as in the
        // strategy-ordering property above.
        assert!(q1 <= q2 * 1.001, "quorum:1 {q1} > quorum:2 {q2} ({plan})");
        assert_eq!(q2, maj, "quorum:2 {q2} != majority {maj} at 3 backups");
        assert!(q2 <= all * 1.001, "quorum:2 {q2} > all {all} ({plan})");
    });
}

#[test]
fn prop_surviving_ledgers_recover_a_committed_prefix() {
    // For random write streams and kill plans, every surviving backup's
    // recovered image is some committed prefix of the primary's history
    // (never ahead of the primary's persist horizon), and once every
    // ledger has drained, some survivor holds the full durable prefix.
    use pmsm::config::{AckPolicy, ReplicationConfig};
    use pmsm::net::{FaultsConfig, OnLoss};
    check("survivor-prefix", 10, |g| {
        let kind = strategy_of(g);
        let txns = g.u64(2, 5);
        let log = log_base_for(0);
        let addrs: Vec<u64> = (0..3).map(|i| 0x5000_0000 + i * 64).collect();
        let drive = |m: &mut Mirror| -> (TxnHistory, u64) {
            let mut t = ThreadCtx::new(0);
            let mut hist = TxnHistory::new(HashMap::new());
            let mut img: HashMap<u64, u64> = HashMap::new();
            for i in 0..txns {
                let mut tx = Txn::begin(m, &mut t, log, None);
                for k in 0..2u64 {
                    let a = addrs[((i + k) % 3) as usize];
                    let v = i * 10 + k;
                    tx.write(m, &mut t, a, v);
                    img.insert(a, v);
                }
                tx.commit(m, &mut t);
                if m.stall().is_some() {
                    break;
                }
                hist.commit(img.clone(), t.last_dfence);
            }
            (hist, t.now())
        };
        // Fault-free dry run places the kill.
        let repl = ReplicationConfig::new(3, AckPolicy::Quorum(1));
        let mut dry = Mirror::with_replication(Platform::default(), kind, repl, true).unwrap();
        let (_, span) = drive(&mut dry);
        let victim = g.usize(0, 2);
        let kill_at = g.u64(1, span.max(2) - 1);
        let faults =
            FaultsConfig::with_plan(&format!("kill:{victim}@{kill_at}"), OnLoss::Degrade)
                .unwrap();
        let mut m = Mirror::try_build_faulted(
            Platform::default(),
            kind,
            None,
            repl,
            faults,
            true,
        )
        .unwrap();
        let (hist, end) = drive(&mut m);
        m.settle(end);
        let timeline = m.fabric().timeline();
        let ledgers = m.fabric().ledgers();
        // Crash horizon at which every surviving ledger has drained.
        let horizon = ledgers.iter().map(|l| l.horizon()).max().unwrap_or(0);
        let alive = timeline.alive_at(horizon);
        let mut best = 0usize;
        for (b, ledger) in ledgers.iter().enumerate() {
            if !alive[b] {
                continue;
            }
            // Guarantee-1 on every survivor, at random instants and at
            // the drained horizon; never ahead of the primary's history.
            for t in [g.u64(0, horizon.max(1)), horizon] {
                let k = recovery::best_prefix(ledger, &hist, &[log], &addrs, t)
                    .unwrap_or_else(|e| panic!("{kind:?} backup {b}: {e}"));
                assert!(
                    k <= hist.committed(),
                    "{kind:?} backup {b}: prefix {k} ahead of primary ({})",
                    hist.committed()
                );
                if t == horizon {
                    best = best.max(k);
                }
            }
        }
        // The two never-killed backups received the full stream, so the
        // best drained survivor holds every durably-acked transaction.
        assert!(
            best >= hist.durable_by(horizon),
            "{kind:?}: best survivor prefix {best} < durable {}",
            hist.durable_by(horizon)
        );
    });
}

#[test]
fn prop_ledger_image_respects_crash_time() {
    check("ledger-image", 60, |g| {
        use pmsm::mem::{DurEvent, DurabilityLog};
        let mut log = DurabilityLog::new(true);
        let n = g.u64(1, 40);
        let mut events = Vec::new();
        for i in 0..n {
            let ev = DurEvent {
                addr: g.u64(0, 8) * 64,
                val: g.u64(0, 1000),
                at: g.u64(0, 10_000),
                thread: 0,
                txn: i,
                epoch: 0,
                seq: i,
            };
            log.record(ev);
            events.push(ev);
        }
        let t = g.u64(0, 12_000);
        let img = log.image_at(t);
        // No value from the future.
        for (addr, val) in &img {
            assert!(
                events
                    .iter()
                    .any(|e| e.addr == *addr && e.val == *val && e.at <= t),
                "image contains future/phantom value"
            );
        }
        // Every address with a past event is present.
        for e in &events {
            if e.at <= t {
                assert!(img.contains_key(&e.addr));
            }
        }
    });
}
