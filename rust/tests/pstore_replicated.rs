//! Integration: persistent data structures under active replication —
//! the structures must stay functionally correct while every mutation is
//! mirrored, and the backup must converge to the primary.

use pmsm::config::{Platform, StrategyKind};
use pmsm::coordinator::{Mirror, ThreadCtx};
use pmsm::pstore::{log_base_for, CritBitTree, KvStore, NStore, PHashMap, PmHeap};
use pmsm::txn::Txn;
use pmsm::util::Pcg64;

fn backup_equals_primary(m: &Mirror) -> bool {
    let ledger = &m.backup(0).ledger;
    let img = ledger.image_at(ledger.horizon());
    m.image().iter().all(|(a, v)| img.get(a) == Some(v))
}

#[test]
fn cbtree_correct_under_every_strategy() {
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        let mut m = Mirror::new(Platform::default(), kind, true);
        let mut t = ThreadCtx::new(0);
        let mut heap = PmHeap::new();
        let mut tree = CritBitTree::new(0);
        let log = log_base_for(0);
        let mut rng = Pcg64::new(42);
        let mut oracle = std::collections::BTreeMap::new();
        for _ in 0..120 {
            let k = rng.next_below(40);
            if rng.chance(0.65) {
                let v = rng.next_u64() | 1;
                tree.insert(&mut m, &mut t, &mut heap, k, v, log, None);
                oracle.insert(k, v);
            } else {
                assert_eq!(
                    tree.remove(&mut m, &mut t, &mut heap, k, log, None),
                    oracle.remove(&k).is_some(),
                    "{kind}: remove {k}"
                );
            }
        }
        for (&k, &v) in &oracle {
            assert_eq!(tree.get(&mut m, &mut t, k), Some(v), "{kind}: get {k}");
        }
        assert!(backup_equals_primary(&m), "{kind}: backup diverged");
    }
}

#[test]
fn hashmap_backup_converges() {
    for kind in [StrategyKind::SmOb, StrategyKind::SmDd] {
        let mut m = Mirror::new(Platform::default(), kind, true);
        let mut t = ThreadCtx::new(0);
        let mut heap = PmHeap::new();
        let mut map = PHashMap::create(&mut heap, 64);
        let log = log_base_for(0);
        for k in 0..100u64 {
            map.put(&mut m, &mut t, &mut heap, k, k * 3, log, None);
        }
        for k in (0..100u64).step_by(2) {
            map.remove(&mut m, &mut t, &mut heap, k, log, None);
        }
        assert_eq!(map.len(), 50);
        assert!(backup_equals_primary(&m), "{kind}: backup diverged");
    }
}

#[test]
fn kvstore_batches_replicate_atomically() {
    let mut m = Mirror::new(Platform::default(), StrategyKind::SmOb, true);
    let mut t = ThreadCtx::new(0);
    let mut heap = PmHeap::new();
    let mut kv = KvStore::create(&mut heap, 256, 0);
    let log = log_base_for(0);
    for b in 0..5u64 {
        let batch: Vec<(u64, u64)> = (0..30).map(|k| (k, b * 1000 + k)).collect();
        kv.apply_batch(&mut m, &mut t, &mut heap, &batch, log);
    }
    assert_eq!(kv.generation(&m), 5);
    assert!(backup_equals_primary(&m));
    // Crash mid-stream: the recovered generation counter and data must
    // come from the same consistent batch prefix.
    let ledger = &m.backup(0).ledger;
    let mid = ledger.horizon() / 2;
    let img = pmsm::recovery::recover_image(ledger, mid, &[log]);
    let gen = img
        .get(&(pmsm::pstore::REGION_ROOTS + 1000 * 64))
        .copied()
        .unwrap_or(0);
    assert!(gen <= 5);
    // Key 0 of the last durable generation must match that generation.
    if gen > 0 {
        // Find key 0's node value via the primary layout is non-trivial
        // from the raw image; assert the ledger-consistency invariant
        // instead: no value from a batch newer than gen+1 exists.
        let max_val = img
            .values()
            .filter(|v| **v >= 1000 && **v < 10_000)
            .max()
            .copied()
            .unwrap_or(0);
        assert!(
            max_val < (gen + 1) * 1000 + 100,
            "value {max_val} from future batch visible at gen {gen}"
        );
    }
}

#[test]
fn nstore_multi_table_txn_replicates() {
    let mut m = Mirror::new(Platform::default(), StrategyKind::SmDd, true);
    let mut t = ThreadCtx::new(0);
    let mut heap = PmHeap::new();
    let mut db = NStore::new();
    let a = db.create_table("a", 2);
    let b = db.create_table("b", 2);
    let log = log_base_for(0);

    let mut tx = Txn::begin(&mut m, &mut t, log, None);
    db.insert(&mut m, &mut t, &mut tx, &mut heap, a, &[1, 10]);
    db.insert(&mut m, &mut t, &mut tx, &mut heap, b, &[1, 20]);
    tx.commit(&mut m, &mut t);

    let mut tx = Txn::begin(&mut m, &mut t, log, None);
    db.update(&mut m, &mut t, &mut tx, a, 1, 1, 11);
    db.update(&mut m, &mut t, &mut tx, b, 1, 1, 21);
    tx.commit(&mut m, &mut t);

    assert_eq!(db.select(&mut m, &mut t, a, 1, 1), Some(11));
    assert_eq!(db.select(&mut m, &mut t, b, 1, 1), Some(21));
    assert!(backup_equals_primary(&m));
}

#[test]
fn heavy_churn_keeps_ledger_ordered() {
    // Interleave structure types on one thread; epoch ordering must hold
    // across all of it.
    let mut m = Mirror::new(Platform::default(), StrategyKind::SmOb, true);
    let mut t = ThreadCtx::new(0);
    let mut heap = PmHeap::new();
    let mut tree = CritBitTree::new(0);
    let mut map = PHashMap::create(&mut heap, 64);
    let log = log_base_for(0);
    let mut rng = Pcg64::new(9);
    for i in 0..60u64 {
        if rng.chance(0.5) {
            tree.insert(&mut m, &mut t, &mut heap, rng.next_below(64), i, log, None);
        } else {
            map.put(&mut m, &mut t, &mut heap, rng.next_below(64), i, log, None);
        }
    }
    pmsm::recovery::check_epoch_ordering(&m.backup(0).ledger).unwrap();
    assert!(m.backup(0).ledger.len() > 200);
}
