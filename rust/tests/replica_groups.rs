//! Integration: N-way replica groups end-to-end — the `backups = 1`
//! regression anchor, full-group mirroring for every strategy, ack-policy
//! latency ordering, and cross-replica ledger consistency under injected
//! single-backup failures (the acceptance scenario: `backups = 3` with
//! `All` and `Quorum(2)`).

use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{Mirror, ThreadCtx};
use pmsm::metrics::GroupReport;
use pmsm::pstore::log_base_for;
use pmsm::recovery::{
    best_prefix, check_group_crashes, check_group_epoch_ordering, TxnHistory,
};
use pmsm::runtime::fallback_predictor;
use pmsm::txn::Txn;
use pmsm::workloads::{run_transact, run_transact_with, TransactConfig};
use std::collections::HashMap;

fn cfg(epochs: u32, writes: u32, txns: u64) -> TransactConfig {
    TransactConfig {
        epochs,
        writes,
        txns,
        ..Default::default()
    }
}

/// The end-to-end regression anchor: for **all five strategies**, the
/// replica-group path with `backups = 1, ack_policy = all` must report
/// bit-identical makespans/throughput to the classic single-backup path.
#[test]
fn backups1_all_reproduces_single_backup_for_all_strategies() {
    let p = Platform::default();
    let repl = ReplicationConfig::default();
    assert_eq!(repl.backups, 1);
    assert_eq!(repl.ack_policy, AckPolicy::All);
    // TABLE = the predictor-free fixed strategies; SM-AD (the fifth
    // member of StrategyKind::ALL) runs right after with an explicit
    // predictor on both paths.
    for kind in StrategyKind::TABLE {
        let c = cfg(4, 2, 100);
        let classic = run_transact(&p, kind, c);
        let grouped = run_transact_with(&p, kind, None, repl, c).unwrap();
        assert_eq!(
            classic.makespan, grouped.makespan,
            "{kind}: makespan diverged"
        );
        assert_eq!(classic.txns, grouped.txns, "{kind}");
        assert_eq!(classic.writes, grouped.writes, "{kind}");
        assert_eq!(
            classic.txn_per_sec(),
            grouped.txn_per_sec(),
            "{kind}: throughput diverged"
        );
    }
    // SM-AD with the same predictor on both paths.
    let c = cfg(4, 1, 60);
    let classic = pmsm::workloads::transact::run_transact_adaptive(
        &p,
        fallback_predictor(&p),
        c,
    );
    let grouped = run_transact_with(
        &p,
        StrategyKind::SmAd,
        Some(fallback_predictor(&p)),
        repl,
        c,
    )
    .unwrap();
    assert_eq!(classic.makespan, grouped.makespan, "sm-ad: makespan diverged");
}

/// Every backup of a 3-way group receives the full write stream and
/// independently satisfies the epoch-ordering invariant.
#[test]
fn full_group_mirroring_and_ordering() {
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        let repl = ReplicationConfig::new(3, AckPolicy::All);
        let mut m =
            Mirror::with_replication(Platform::default(), kind, repl, true).unwrap();
        let mut t = ThreadCtx::new(0);
        let log = log_base_for(0);
        for i in 0..6u64 {
            let mut tx = Txn::begin(&mut m, &mut t, log, None);
            tx.write(&mut m, &mut t, 0x4000_0000 + (i % 3) * 64, i);
            tx.commit(&mut m, &mut t);
        }
        let ledgers = m.fabric().ledgers();
        check_group_epoch_ordering(&ledgers).unwrap_or_else(|e| panic!("{kind}: {e}"));
        let len0 = ledgers[0].len();
        assert!(len0 > 0, "{kind}: empty ledger");
        for (b, l) in ledgers.iter().enumerate() {
            assert_eq!(l.len(), len0, "{kind}: backup {b} write count diverged");
        }
        // All-policy dfence covers the slowest backup.
        assert!(
            t.last_dfence >= m.fabric().group_horizon(),
            "{kind}: dfence {} < group horizon {}",
            t.last_dfence,
            m.fabric().group_horizon()
        );
    }
}

/// Acceptance scenario: with `backups = 3`, the cross-replica ledger
/// consistency check passes under injected failures for `All` and
/// `Quorum(2)` — after losing any tolerated set of backups, the best
/// surviving replica still recovers every durably-acked transaction.
#[test]
fn group_recovery_under_injected_failures() {
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        for policy in [AckPolicy::All, AckPolicy::Quorum(2)] {
            let repl = ReplicationConfig::new(3, policy);
            let mut m = Mirror::with_replication(Platform::default(), kind, repl, true)
                .unwrap();
            let mut t = ThreadCtx::new(0);
            let log = log_base_for(0);
            let d0 = 0x6000_0000u64;
            let d1 = 0x6000_0040u64;
            let mut hist = TxnHistory::new(HashMap::new());
            for i in 0..5u64 {
                let mut tx = Txn::begin(&mut m, &mut t, log, None);
                tx.write(&mut m, &mut t, d0, 10 + i);
                tx.write(&mut m, &mut t, d1, 20 + i);
                tx.commit(&mut m, &mut t);
                let mut snap = HashMap::new();
                snap.insert(d0, 10 + i);
                snap.insert(d1, 20 + i);
                hist.commit(snap, t.last_dfence);
            }
            let ledgers = m.fabric().ledgers();
            let checked = check_group_crashes(
                &ledgers,
                &hist,
                &[log],
                &[d0, d1],
                repl.required(),
            )
            .unwrap_or_else(|e| panic!("{kind}/{policy}: {e}"));
            assert!(checked > 20, "{kind}/{policy}: only {checked} crash points");

            // Explicit injected-failure sweep: drop each backup in turn
            // at every ledger event instant; the policy tolerates
            // `required - 1` losses, so with one loss the best survivor
            // must hold every durably-acked txn.
            let mut times: Vec<u64> = ledgers
                .iter()
                .flat_map(|l| l.events().iter().map(|e| e.at))
                .collect();
            times.sort_unstable();
            times.dedup();
            for &crash in &times {
                let durable = hist.durable_by(crash);
                for failed in 0..3usize {
                    let best = (0..3)
                        .filter(|&b| b != failed)
                        .map(|b| {
                            best_prefix(ledgers[b], &hist, &[log], &[d0, d1], crash)
                                .unwrap_or_else(|e| panic!("{kind}/{policy}: {e}"))
                        })
                        .max()
                        .unwrap();
                    assert!(
                        best >= durable,
                        "{kind}/{policy}: crash {crash}, backup {failed} \
                         lost: survivors hold prefix {best} < durable {durable}"
                    );
                }
            }
        }
    }
}

/// Ack-policy latency ordering end-to-end: quorum fences never complete
/// later than all-fences on the same group, and a bigger All-group is
/// never faster than a smaller one.
#[test]
fn policy_latency_ordering() {
    let p = Platform::default();
    let c = cfg(8, 1, 80);
    let mk = |backups, policy| {
        run_transact_with(
            &p,
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(backups, policy),
            c,
        )
        .unwrap()
        .makespan
    };
    let b1 = mk(1, AckPolicy::All);
    let b3_all = mk(3, AckPolicy::All);
    let b3_q2 = mk(3, AckPolicy::Quorum(2));
    let b5_all = mk(5, AckPolicy::All);
    assert!(b3_all >= b1, "3-backup All {b3_all} < single {b1}");
    assert!(b5_all >= b3_all, "5-backup All {b5_all} < 3-backup {b3_all}");
    assert!(b3_q2 <= b3_all, "quorum:2 {b3_q2} > All {b3_all}");
}

/// The per-backup metrics surface: group reports and the scheduler's
/// per-backup horizons agree with the fabric.
#[test]
fn group_metrics_surface() {
    let p = Platform::default();
    let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
    let mut m =
        Mirror::with_replication(p.clone(), StrategyKind::SmDd, repl, false).unwrap();
    let out = pmsm::workloads::transact::run_transact_on(&mut m, cfg(4, 1, 50));
    assert_eq!(out.per_backup_horizon.len(), 3);
    let report = GroupReport::from_fabric(m.fabric());
    assert_eq!(report.backups(), 3);
    assert_eq!(report.required, 2);
    for (s, &h) in report.stats.iter().zip(&out.per_backup_horizon) {
        assert_eq!(s.persist_horizon, h, "backup {}", s.id);
        assert_eq!(s.writes, 200, "backup {} saw a partial stream", s.id);
    }
    assert!(report.blocking_waits >= 50, "one fence per txn");
    let rendered = report.render();
    assert!(rendered.contains("quorum:2"), "{rendered}");
}
