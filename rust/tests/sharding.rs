//! Sharding acceptance tests: the `shards = 1` regression anchor
//! (event-for-event identical to the pre-shard single-fabric path), the
//! cross-shard recovery property (merged verdict consistent iff every
//! shard's prefix is individually consistent), and the shards=4 x
//! backups=2 end-to-end commit + recover scenario.

use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{Mirror, ShardMapSpec, ShardingConfig, ThreadCtx};
use pmsm::mem::DurabilityLog;
use pmsm::net::{FaultsConfig, OnLoss};
use pmsm::pstore::log_base_for;
use pmsm::ptest::check;
use pmsm::recovery::{self, TxnHistory};
use pmsm::txn::Txn;
use pmsm::workloads::transact::{run_transact_on, run_transact_sharded};
use pmsm::workloads::{run_transact_with, TransactConfig};
use std::collections::HashMap;

fn cfg(txns: u64) -> TransactConfig {
    TransactConfig {
        epochs: 4,
        writes: 2,
        txns,
        ..Default::default()
    }
}

fn sharded_mirror(
    kind: StrategyKind,
    shards: usize,
    map: ShardMapSpec,
    backups: usize,
    policy: AckPolicy,
    ledger: bool,
) -> Mirror {
    Mirror::try_build_sharded(
        Platform::default(),
        kind,
        None,
        ReplicationConfig::new(backups, policy),
        FaultsConfig::default(),
        ShardingConfig::new(shards, map),
        ledger,
    )
    .unwrap()
}

/// The pinning test: a `shards = 1` mirror — under *any* map spec — is
/// event-for-event identical to the single-fabric path: same makespan,
/// same ledger event stream on every backup, same persist horizons.
#[test]
fn shards_1_pins_single_fabric_event_stream() {
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        let repl = ReplicationConfig::new(2, AckPolicy::All);
        let mut single =
            Mirror::with_replication(Platform::default(), kind, repl, true).unwrap();
        let base_out = run_transact_on(&mut single, cfg(50));
        for map in [
            ShardMapSpec::Modulo,
            ShardMapSpec::Range { stripe_lines: 128 },
        ] {
            let mut m = sharded_mirror(kind, 1, map, 2, AckPolicy::All, true);
            let out = run_transact_on(&mut m, cfg(50));
            assert_eq!(out.makespan, base_out.makespan, "{kind:?}/{map}");
            assert_eq!(out.txns, base_out.txns, "{kind:?}/{map}");
            assert_eq!(out.shards, 1, "{kind:?}/{map}");
            assert_eq!(
                out.per_backup_horizon, base_out.per_backup_horizon,
                "{kind:?}/{map}"
            );
            for b in 0..2 {
                assert_eq!(
                    single.backup(b).ledger.events(),
                    m.backup(b).ledger.events(),
                    "{kind:?}/{map}: backup {b} event stream diverged"
                );
            }
        }
    }
}

/// More shards never lose writes: every line lands on exactly one
/// shard, and the per-shard ledger totals sum to the full write stream
/// on every backup index.
#[test]
fn shard_partition_conserves_the_write_stream() {
    let c = cfg(100);
    let single = run_transact_with(
        &Platform::default(),
        StrategyKind::SmOb,
        None,
        ReplicationConfig::new(2, AckPolicy::All),
        c,
    )
    .unwrap();
    for (shards, map) in [
        (2, ShardMapSpec::Modulo),
        (4, ShardMapSpec::Modulo),
        (4, ShardMapSpec::Range { stripe_lines: 64 }),
    ] {
        let mut m = sharded_mirror(StrategyKind::SmOb, shards, map, 2, AckPolicy::All, true);
        let out = run_transact_on(&mut m, c);
        assert_eq!(out.txns, c.txns, "{shards}/{map}");
        assert_eq!(out.writes, single.writes, "{shards}/{map}");
        for b in 0..2 {
            let total: usize = (0..shards)
                .map(|s| m.shard_fabric(s).backup(b).ledger.len())
                .sum();
            assert_eq!(
                total as u64, single.writes,
                "{shards}/{map}: backup {b} lost or duplicated writes"
            );
        }
    }
}

/// Drive `txns` two-write transactions over a (sharded) mirror,
/// recording the golden history.
fn drive(m: &mut Mirror, txns: u64, d0: u64, d1: u64) -> TxnHistory {
    let mut t = ThreadCtx::new(0);
    let log = log_base_for(0);
    let mut hist = TxnHistory::new(HashMap::new());
    for i in 0..txns {
        let mut tx = Txn::begin(m, &mut t, log, None);
        tx.write(m, &mut t, d0, 100 + i);
        tx.write(m, &mut t, d1, 200 + i);
        tx.commit(m, &mut t);
        let mut snap = HashMap::new();
        snap.insert(d0, 100 + i);
        snap.insert(d1, 200 + i);
        hist.commit(snap, t.last_dfence);
    }
    m.settle(t.now());
    hist
}

/// Acceptance scenario: a `shards = 4, backups = 2` end-to-end run
/// commits every transaction and the cross-shard recovery sweep holds
/// at every crash point, for every strategy and both map families.
#[test]
fn sharded_end_to_end_commits_and_recovers() {
    let d0 = 0x20_0000u64;
    let d1 = 0x20_0040u64;
    let log = log_base_for(0);
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        for map in [ShardMapSpec::Modulo, ShardMapSpec::Range { stripe_lines: 2 }] {
            let mut m = sharded_mirror(kind, 4, map, 2, AckPolicy::All, true);
            let hist = drive(&mut m, 5, d0, d1);
            assert_eq!(hist.committed(), 5, "{kind:?}/{map}");
            let ledgers = m.shard_ledgers();
            for (s, ls) in ledgers.iter().enumerate() {
                recovery::check_group_epoch_ordering(ls)
                    .unwrap_or_else(|e| panic!("{kind:?}/{map} shard {s}: {e}"));
            }
            let checked = recovery::check_sharded_group_crashes(
                &ledgers,
                &m.timelines(),
                &hist,
                &[log],
                &[d0, d1],
                2,
                OnLoss::Halt,
                m.shard_map(),
            )
            .unwrap_or_else(|e| panic!("{kind:?}/{map}: {e}"));
            assert!(checked > 10, "{kind:?}/{map}: only {checked} crash points");
        }
    }
}

/// Property (cross-shard verdict): for random shard counts, maps, and
/// workloads, the merged verdict is consistent — and corrupting a
/// single random shard's ledgers (dropping a durable suffix) makes the
/// merged verdict fail, i.e. the merge is exactly as strong as its
/// weakest shard.
#[test]
fn prop_merged_verdict_iff_every_shard_consistent() {
    check("sharded-verdict", 12, |g| {
        let shards = g.u64(2, 5) as usize;
        let txns = g.u64(2, 5);
        let stripe = g.u64(1, 8);
        let map = if g.u64(0, 1) == 0 {
            ShardMapSpec::Modulo
        } else {
            ShardMapSpec::Range { stripe_lines: stripe }
        };
        let d0 = 0x20_0000u64;
        let d1 = 0x20_0040u64 + g.u64(0, 3) * 64;
        let log = log_base_for(0);
        let mut m =
            sharded_mirror(StrategyKind::SmOb, shards, map, 2, AckPolicy::All, true);
        let hist = drive(&mut m, txns, d0, d1);
        let ledgers = m.shard_ledgers();
        let tls = m.timelines();
        let smap = *m.shard_map();
        // Forward direction: the real run passes everywhere.
        recovery::check_sharded_group_crashes(
            &ledgers,
            &tls,
            &hist,
            &[log],
            &[d0, d1],
            2,
            OnLoss::Halt,
            &smap,
        )
        .unwrap();
        // Backward direction: blank out the shard owning d1 on every
        // backup — its prefix collapses below the durable count, so the
        // merged verdict must fail at the final crash point, while the
        // other shards' restricted checks still pass.
        let victim = smap.shard_of(d1);
        let empty = DurabilityLog::new(true);
        let corrupted: Vec<Vec<&DurabilityLog>> = ledgers
            .iter()
            .enumerate()
            .map(|(s, ls)| {
                if s == victim {
                    ls.iter().map(|_| &empty).collect()
                } else {
                    ls.clone()
                }
            })
            .collect();
        let crash = ledgers
            .iter()
            .flatten()
            .map(|l| l.horizon())
            .max()
            .unwrap();
        assert!(hist.durable_by(crash) > 0, "something must be durable");
        let err = recovery::check_sharded_group_crash(
            &corrupted,
            &tls,
            &hist,
            &[log],
            &[d0, d1],
            2,
            OnLoss::Halt,
            &smap,
            crash,
        );
        assert!(
            err.is_err(),
            "an inconsistent shard must sink the merged verdict \
             (shards={shards}, map={map}, victim={victim})"
        );
        // The healthy run's verdict at the same point equals the full
        // history — nothing is lost by the merge itself.
        let k = recovery::check_sharded_group_crash(
            &ledgers,
            &tls,
            &hist,
            &[log],
            &[d0, d1],
            2,
            OnLoss::Halt,
            &smap,
            crash,
        )
        .unwrap();
        assert_eq!(k as u64, txns, "merged verdict covers the full history");
    });
}

/// Sharding composes with fault injection: killing backup node 1 kills
/// replica 1 of every shard; degrade completes on the survivors and the
/// fault-aware sharded sweep accepts the realized timelines.
#[test]
fn sharded_run_with_faults_degrades_and_recovers() {
    let d0 = 0x20_0000u64;
    let d1 = 0x20_0040u64;
    let log = log_base_for(0);
    let mut m = Mirror::try_build_sharded(
        Platform::default(),
        StrategyKind::SmOb,
        None,
        ReplicationConfig::new(2, AckPolicy::All),
        FaultsConfig::with_plan("kill:1@20000", OnLoss::Degrade).unwrap(),
        ShardingConfig::new(2, ShardMapSpec::Modulo),
        true,
    )
    .unwrap();
    let hist = drive(&mut m, 8, d0, d1);
    assert!(m.stall().is_none(), "degrade must complete");
    assert_eq!(hist.committed(), 8);
    for s in 0..2 {
        // The kill applies to every shard's replica 1 once its verb
        // stream reaches the kill instant.
        assert!(
            !m.shard_fabric(s).state(1).is_alive(),
            "shard {s} replica 1 should be dead"
        );
    }
    recovery::check_sharded_group_crashes(
        &m.shard_ledgers(),
        &m.timelines(),
        &hist,
        &[log],
        &[d0, d1],
        2,
        OnLoss::Degrade,
        m.shard_map(),
    )
    .expect("fault-aware sharded sweep");
}

/// Sharded throughput sanity at the workload level: the sharded run
/// commits the full transaction count and, for the All policy, more
/// shards never reduce the committed count or lose per-backup horizons.
#[test]
fn sharded_transact_outcome_shape() {
    let c = cfg(80);
    for shards in [1usize, 2, 4] {
        let out = run_transact_sharded(
            &Platform::default(),
            StrategyKind::SmOb,
            ReplicationConfig::new(2, AckPolicy::All),
            ShardingConfig::new(shards, ShardMapSpec::Modulo),
            c,
        )
        .unwrap();
        assert_eq!(out.txns, c.txns, "shards={shards}");
        assert_eq!(out.shards, shards);
        assert_eq!(out.per_backup_horizon.len(), shards * 2);
        assert!(out.stalled.is_none());
    }
    // Invalid shapes surface as errors, not panics.
    assert!(run_transact_sharded(
        &Platform::default(),
        StrategyKind::SmOb,
        ReplicationConfig::new(2, AckPolicy::All),
        ShardingConfig::new(0, ShardMapSpec::Modulo),
        c,
    )
    .is_err());
}
