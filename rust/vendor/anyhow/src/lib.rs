//! Minimal in-repo shim of the `anyhow` API surface used by pmsm.
//!
//! The offline registry carries no crates, so the error-handling
//! vocabulary the codebase relies on — [`Error`], [`Result`],
//! [`Context`], and the `anyhow!` / `bail!` / `ensure!` macros — is
//! provided here. Semantics match upstream for the used subset:
//!
//! * `Error` is an opaque, context-chained error value (deliberately
//!   *not* `std::error::Error`, exactly like upstream, which is what
//!   makes the blanket `From<E: std::error::Error>` conversion legal);
//! * `context`/`with_context` wrap both `Result` (any displayable error,
//!   including `anyhow::Error` itself) and `Option`;
//! * `{:#}` formatting renders the full cause chain `ctx: cause`.

use std::fmt;

/// Opaque error: a message plus an optional chain of wrapped causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            cause: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: c.to_string(),
            cause: Some(Box::new(self)),
        }
    }

    /// The outermost message (no chain).
    pub fn root_message(&self) -> &str {
        &self.msg
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = &self.cause;
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = &e.cause;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` prints the outermost message; `{:#}` the full chain.
        if f.alternate() {
            self.write_chain(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values (`Result` with displayable errors —
/// including `anyhow::Error` — and `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] from format args.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("boom {}", 7)
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 7");
    }

    #[test]
    fn context_chains_and_alternate_renders() {
        let e: Result<u32> = "x".parse::<u32>().with_context(|| "parsing x");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "parsing x");
        assert!(format!("{e:#}").starts_with("parsing x: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<()> {
            ensure!(x > 1, "too small: {x}");
            Ok(())
        }
        assert!(check(2).is_ok());
        assert_eq!(check(0).unwrap_err().to_string(), "too small: 0");
    }

    #[test]
    fn std_errors_convert() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(io().is_err());
    }
}
