//! Stub of the `xla` PJRT binding surface used by `pmsm::runtime`.
//!
//! The build environment has no PJRT plugin or `xla_extension` shared
//! library, so this crate provides the same types and signatures with
//! loaders that return a descriptive [`Error`]. Callers already handle
//! that path: `LatencyModel::load` failures route the adaptive strategy
//! to the closed-form fallback predictor, and the `pjrt_model`
//! integration tests skip when artifacts are absent. Swapping this stub
//! for the real binding requires no changes in `pmsm` itself.

use std::fmt;

/// Error raised by every stubbed entry point.
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT runtime unavailable (stub xla crate; install the \
             xla_extension binding to enable AOT model execution)"
        ))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (stub: carries no data).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a slice (stub: drops the data).
    pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(Error::unavailable("Literal::to_tuple2"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!(
            "HloModuleProto::from_text_file({path})"
        )))
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Loaded executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub: construction reports unavailability).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loaders_report_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("PJRT runtime unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
    }
}
